"""End-to-end FFD registration of a synthetic liver-phantom pair (paper §6-7).

Creates a (fixed, moving) pair with a known smooth deformation (the
synthetic pneumoperitoneum), registers with affine then FFD (BSI inner
loop in the mode of your choice — default ``auto``, the engine autotuner's
winner for this grid/tile), and reports MAE/SSIM (paper Table 5) plus the
BSI share of runtime (paper Fig. 8-9 Amdahl argument).  ``--batch N``
registers N pairs in one jitted program via ``repro.engine.register_batch``.

``--similarity`` picks the loss term the optimiser minimises (see
``repro.core.similarity``); ``--multimodal`` applies a monotone intensity
remap to the moving volume first — the synthetic CT↔CBCT case where SSD
fails and ``--similarity nmi`` recovers the warp.

``--early-stop [TOL]`` swaps the fixed-``--iters`` loops for the
convergence-aware ``lax.while_loop`` (``repro.engine.convergence``): each
pyramid level stops when the loss plateaus and the report shows the Adam
steps actually run.

    python examples/register_volumes.py [--mode auto] [--batch 4]
    python examples/register_volumes.py --multimodal --similarity nmi
    python examples/register_volumes.py --early-stop 1e-4 --batch 4
"""
import argparse
import sys
import time
from pathlib import Path

try:
    import repro  # noqa: F401
except ModuleNotFoundError:  # src-layout checkout without install
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import RegistrationOptions, ffd, metrics
from repro.core.registration import affine_register, ffd_register
from repro.core.similarity import available_similarities
from repro.data.volumes import make_pair
from repro.engine import ConvergenceConfig, register_batch, resolve_bsi


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "gather", "tt", "ttli", "separable",
                             "matmul"])
    ap.add_argument("--shape", type=int, nargs=3, default=(64, 56, 48))
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--batch", type=int, default=0,
                    help="also register a batch of this many pairs in one "
                         "jitted program (repro.engine.register_batch)")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the --batch registrations over every local "
                         "device (engine.shard.make_registration_mesh); on "
                         "CPU fake a pod first: XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=8")
    ap.add_argument("--similarity", default="ssd",
                    choices=available_similarities(),
                    help="loss term the optimiser minimises "
                         "(repro.core.similarity registry)")
    ap.add_argument("--multimodal", action="store_true",
                    help="monotone-remap the moving volume's intensities "
                         "first (synthetic cross-modality pair; use "
                         "--similarity nmi)")
    ap.add_argument("--early-stop", type=float, nargs="?", const=1e-4,
                    default=None, metavar="TOL",
                    help="stop each pyramid level when the loss plateaus "
                         "(relative improvement < TOL for a patience "
                         "window) instead of always running --iters steps "
                         "(repro.engine.convergence.ConvergenceConfig)")
    ap.add_argument("--lr", type=float, default=None,
                    help="Adam learning rate (default: the engine's 0.5, "
                         "or 0.12 with --early-stop — the plateau rule "
                         "wants an lr at which the loss actually descends, "
                         "and 0.5 overshoots for the first ~15 steps at "
                         "this scale)")
    args = ap.parse_args()
    if args.lr is None:
        args.lr = 0.12 if args.early_stop is not None else 0.5
        if args.early_stop is not None:
            print(f"--early-stop: using lr={args.lr} (pass --lr to "
                  "override); see README 'Early stopping'")
    if args.mesh and not args.batch:
        ap.error("--mesh shards the batched path; pass --batch N with it")

    tile = (6, 6, 6)
    shape = tuple(args.shape)
    mode, impl = resolve_bsi(args.mode, "auto",
                             ffd.grid_shape_for_volume(shape, tile), tile,
                             measure_grad=True, similarity=args.similarity)
    print(f"BSI form: {mode}/{impl}"
          + (" (autotuned)" if args.mode == "auto" else "")
          + f"; similarity: {args.similarity}")

    fixed, moving, _ = make_pair(shape=shape, tile=tile,
                                 magnitude=2.2, seed=0)
    source = moving
    if args.multimodal:
        moving = (1.0 - moving) ** 1.5  # monotone intensity remap
        print("multi-modal: moving volume intensities monotonically "
              "remapped; MAE/SSIM scored on the un-remapped volume "
              "warped by the recovered field")
    print(f"pair {fixed.shape}; pre-registration: "
          f"mae={float(metrics.mae(source, fixed)):.4f} "
          f"ssim={float(metrics.ssim(source, fixed)):.4f}")

    if not args.multimodal:
        aff = affine_register(fixed, moving,
                              options=RegistrationOptions(
                                  iters=40, lr=0.02,
                                  similarity=args.similarity))
        print(f"affine      ({aff.seconds:5.1f}s): "
              f"mae={float(metrics.mae(aff.warped, fixed)):.4f} "
              f"ssim={float(metrics.ssim(aff.warped, fixed)):.4f}")

    stop = (ConvergenceConfig(tol=args.early_stop)
            if args.early_stop is not None else None)
    # one options object configures every entry point below (and is the
    # compiled-program cache key — see README "One options object")
    opts = RegistrationOptions(tile=tile, levels=2, iters=args.iters,
                               lr=args.lr, mode=mode, impl=impl,
                               similarity=args.similarity, stop=stop)
    res = ffd_register(fixed, moving, options=opts, measure_bsi_time=True)
    disp = ffd.dense_field(res.params, tile, shape, mode=mode, impl=impl)
    recovered = ffd.warp_volume(source, disp)
    steps_note = ("" if res.steps is None else
                  f", steps/level {res.steps} of {args.iters}")
    print(f"ffd/{mode:9s} ({res.seconds:5.1f}s, "
          f"~{res.bsi_seconds:.1f}s in BSI{steps_note}): "
          f"mae={float(metrics.mae(recovered, fixed)):.4f} "
          f"ssim={float(metrics.ssim(recovered, fixed)):.4f}")

    if args.batch:
        import jax.numpy as jnp

        mesh = None
        label = f"batch x{args.batch}"
        if args.mesh:
            import jax

            from repro.engine import make_registration_mesh

            mesh = make_registration_mesh()
            label += f" over {len(jax.devices())} device(s)"
        pairs = [make_pair(shape=shape, tile=tile, magnitude=2.2, seed=s)
                 for s in range(args.batch)]
        F = jnp.stack([p[0] for p in pairs])
        M = jnp.stack([p[1] for p in pairs])
        sources = M
        if args.multimodal:
            M = (1.0 - M) ** 1.5  # same monotone remap as the single pair
        batch = register_batch(F, M, options=opts, mesh=mesh)
        cold = batch.seconds  # includes the one-time compile
        t0 = time.perf_counter()
        batch = register_batch(F, M, options=opts, mesh=mesh)
        warm = time.perf_counter() - t0
        disp0 = ffd.dense_field(batch.params[0], tile, shape,
                                mode=mode, impl=impl)
        mae = float(metrics.mae(ffd.warp_volume(sources[0], disp0), F[0]))
        steps_note = ("" if batch.steps is None else
                      f", steps {batch.steps.sum(axis=1).tolist()}"
                      f" of {2 * args.iters}")
        print(f"{label} (cold {cold:5.1f}s, warm {warm:5.2f}s"
              f" = {warm / args.batch:5.2f}s/pair{steps_note}): "
              f"mae[0]={mae:.4f}")


if __name__ == "__main__":
    main()
