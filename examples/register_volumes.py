"""End-to-end FFD registration of a synthetic liver-phantom pair (paper §6-7).

Creates a (fixed, moving) pair with a known smooth deformation (the
synthetic pneumoperitoneum), registers with affine then FFD (BSI inner
loop in the mode of your choice), and reports MAE/SSIM (paper Table 5)
plus the BSI share of runtime (paper Fig. 8-9 Amdahl argument).

    PYTHONPATH=src python examples/register_volumes.py [--mode separable]
"""
import argparse
import time

from repro.core import metrics
from repro.core.registration import affine_register, ffd_register
from repro.data.volumes import make_pair


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="separable",
                    choices=["gather", "tt", "ttli", "separable"])
    ap.add_argument("--shape", type=int, nargs=3, default=(64, 56, 48))
    ap.add_argument("--iters", type=int, default=30)
    args = ap.parse_args()

    fixed, moving, _ = make_pair(shape=tuple(args.shape), tile=(6, 6, 6),
                                 magnitude=2.2, seed=0)
    print(f"pair {fixed.shape}; pre-registration: "
          f"mae={float(metrics.mae(moving, fixed)):.4f} "
          f"ssim={float(metrics.ssim(moving, fixed)):.4f}")

    aff = affine_register(fixed, moving, iters=40)
    print(f"affine      ({aff.seconds:5.1f}s): "
          f"mae={float(metrics.mae(aff.warped, fixed)):.4f} "
          f"ssim={float(metrics.ssim(aff.warped, fixed)):.4f}")

    res = ffd_register(fixed, moving, tile=(6, 6, 6), levels=2,
                       iters=args.iters, mode=args.mode,
                       measure_bsi_time=True)
    print(f"ffd/{args.mode:9s} ({res.seconds:5.1f}s, "
          f"~{res.bsi_seconds:.1f}s in BSI): "
          f"mae={float(metrics.mae(res.warped, fixed)):.4f} "
          f"ssim={float(metrics.ssim(res.warped, fixed)):.4f}")


if __name__ == "__main__":
    main()
