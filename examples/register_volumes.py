"""End-to-end FFD registration of a synthetic liver-phantom pair (paper §6-7).

Creates a (fixed, moving) pair with a known smooth deformation (the
synthetic pneumoperitoneum), registers with affine then FFD (BSI inner
loop in the mode of your choice — default ``auto``, the engine autotuner's
winner for this grid/tile), and reports MAE/SSIM (paper Table 5) plus the
BSI share of runtime (paper Fig. 8-9 Amdahl argument).  ``--batch N``
registers N pairs in one jitted program via ``repro.engine.register_batch``.

    python examples/register_volumes.py [--mode auto] [--batch 4]
"""
import argparse
import sys
import time
from pathlib import Path

try:
    import repro  # noqa: F401
except ModuleNotFoundError:  # src-layout checkout without install
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import ffd, metrics
from repro.core.registration import affine_register, ffd_register
from repro.data.volumes import make_pair
from repro.engine import register_batch, resolve_bsi


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "gather", "tt", "ttli", "separable"])
    ap.add_argument("--shape", type=int, nargs=3, default=(64, 56, 48))
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--batch", type=int, default=0,
                    help="also register a batch of this many pairs in one "
                         "jitted program (repro.engine.register_batch)")
    args = ap.parse_args()

    tile = (6, 6, 6)
    shape = tuple(args.shape)
    mode, impl = resolve_bsi(args.mode, "auto",
                             ffd.grid_shape_for_volume(shape, tile), tile,
                             measure_grad=True)
    print(f"BSI form: {mode}/{impl}"
          + (" (autotuned)" if args.mode == "auto" else ""))

    fixed, moving, _ = make_pair(shape=shape, tile=tile,
                                 magnitude=2.2, seed=0)
    print(f"pair {fixed.shape}; pre-registration: "
          f"mae={float(metrics.mae(moving, fixed)):.4f} "
          f"ssim={float(metrics.ssim(moving, fixed)):.4f}")

    aff = affine_register(fixed, moving, iters=40)
    print(f"affine      ({aff.seconds:5.1f}s): "
          f"mae={float(metrics.mae(aff.warped, fixed)):.4f} "
          f"ssim={float(metrics.ssim(aff.warped, fixed)):.4f}")

    res = ffd_register(fixed, moving, tile=tile, levels=2,
                       iters=args.iters, mode=mode, impl=impl,
                       measure_bsi_time=True)
    print(f"ffd/{mode:9s} ({res.seconds:5.1f}s, "
          f"~{res.bsi_seconds:.1f}s in BSI): "
          f"mae={float(metrics.mae(res.warped, fixed)):.4f} "
          f"ssim={float(metrics.ssim(res.warped, fixed)):.4f}")

    if args.batch:
        import jax.numpy as jnp

        pairs = [make_pair(shape=shape, tile=tile, magnitude=2.2, seed=s)
                 for s in range(args.batch)]
        F = jnp.stack([p[0] for p in pairs])
        M = jnp.stack([p[1] for p in pairs])
        batch = register_batch(F, M, tile=tile, levels=2, iters=args.iters,
                               mode=mode, impl=impl)
        cold = batch.seconds  # includes the one-time compile
        t0 = time.perf_counter()
        batch = register_batch(F, M, tile=tile, levels=2, iters=args.iters,
                               mode=mode, impl=impl)
        warm = time.perf_counter() - t0
        mae = float(metrics.mae(batch.warped[0], fixed))
        print(f"batch x{args.batch} (cold {cold:5.1f}s, warm {warm:5.2f}s"
              f" = {warm / args.batch:5.2f}s/pair): mae[0]={mae:.4f}")


if __name__ == "__main__":
    main()
