"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the full production path — config, synthetic data pipeline, AdamW,
checkpointing (atomic keep-k + resume), straggler watchdog — on a single
CPU device.  Default config is a 100M-class dense model (internlm2 family
geometry, scaled); loss should drop steadily on the motif-structured
synthetic stream.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200   # resumes!
"""
import argparse

from repro.configs.base import ModelConfig
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch.train import TrainLoop
from repro.optim.optimizer import OptConfig


def model_100m():
    return ModelConfig(
        name="demo-100m", family="dense",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        d_ff=2048, vocab_size=32768, head_dim=64,
        dtype="float32", remat=False,
        loss_chunk=256, attn_q_chunk=256, attn_kv_chunk=256,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--out", default="/tmp/repro_train_100m")
    ap.add_argument("--tiny", action="store_true",
                    help="~4M params (fast CI check)")
    args = ap.parse_args()

    cfg = model_100m()
    if args.tiny:
        cfg = ModelConfig(**{**cfg.__dict__, "num_layers": 2, "d_model": 128,
                             "d_ff": 512, "vocab_size": 4096,
                             "name": "demo-tiny"})
    n_params = sum(
        int(__import__("numpy").prod(p.shape))
        for p in __import__("jax").tree_util.tree_leaves(
            __import__("repro.models.model", fromlist=["abstract_model"])
            .abstract_model(cfg))
    )
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")

    loop = TrainLoop(cfg, OptConfig(lr=3e-3, warmup_steps=20,
                                    total_steps=args.steps), args.out)
    start = loop.init_or_restore()
    print(f"starting at step {start}")
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))
    losses = loop.run(pipe, args.steps, ckpt_every=50, log_every=10)
    if losses:
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"over {len(losses)} steps; stragglers={loop.stragglers}")


if __name__ == "__main__":
    main()
