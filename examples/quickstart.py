"""Quickstart: B-spline interpolation in all five algorithm forms.

Shows the paper's core operation — expanding a coarse control grid into a
dense deformation field — plus the generic-interpolation use from paper §8
(2-D image zoom via a 3-D grid with a flat z axis), validated against the
float-oracle and timed.

    python examples/quickstart.py [--tiny]

``--tiny`` shrinks the volumes to CI-smoke size (compile + run every form
in seconds) — the CI gate runs exactly that.
"""
import argparse
import sys
import time
from pathlib import Path

try:
    import repro  # noqa: F401
except ModuleNotFoundError:  # src-layout checkout without install
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ffd
from repro.core.interpolate import MODE_NAMES, interpolate
from repro.kernels import ops
from repro.kernels.ref import bsi_ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-smoke sizes (seconds, not minutes, on CPU)")
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    # --- 1. dense deformation field from a control grid (the FFD inner loop)
    tile = (5, 5, 5)                       # NiftyReg's default spacing
    vol = (30, 25, 20) if args.tiny else (80, 75, 70)
    gshape = ffd.grid_shape_for_volume(vol, tile)
    phi = jnp.asarray(rng.standard_normal(gshape + (3,)), jnp.float32)

    ref = bsi_ref(phi, tile)
    print(f"control grid {phi.shape} -> dense field {ref.shape}")
    for mode in MODE_NAMES:
        fn = jax.jit(lambda p, m=mode: interpolate(p, tile, mode=m))
        out = fn(phi)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(phi))
        dt = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(out - ref)))
        print(f"  {mode:10s}: {dt*1e3:7.1f} ms   max|err vs oracle| = {err:.2e}")

    # --- 2. the same kernels in Pallas (TPU target, interpret mode on CPU)
    out = ops.bsi_pallas(phi, tile, mode="ttli")
    print(f"pallas ttli: max|err| = {float(jnp.max(jnp.abs(out - ref))):.2e}")

    # --- 3. generic image zoom (paper §8): pixels as control points
    img = jnp.asarray(rng.standard_normal((36, 36)), jnp.float32)
    phi2d = img[:, :, None, None]          # (nx, ny, 1-ish z, C=1)
    phi2d = jnp.broadcast_to(phi2d, (36, 36, 4, 1))
    zoom = interpolate(phi2d, (4, 4, 1), mode="separable")
    print(f"2-D zoom: {img.shape} -> {zoom.shape[:2]} (4x upsampling)")


if __name__ == "__main__":
    main()
