"""Batched serving demo: prefill + decode with bf16 vs int8 KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b
"""
import argparse
import time

import numpy as np

from repro.configs.base import get_config
from repro.launch.serve import generate, make_generate_steps
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = M.init_model(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    max_len = args.prompt_len + args.gen + 1

    for kv in ("bfloat16", "int8"):
        c = cfg.__class__(**{**cfg.__dict__, "kv_cache_dtype": kv})
        # warm up on prebuilt jitted steps, then time the warm path only —
        # a single timed call would mostly measure trace + compile, not the
        # serving throughput the printed tok/s claims to be
        steps = make_generate_steps(c, max_len)
        toks, _ = generate(c, params, prompts, max_len, args.gen,
                           steps=steps)
        np.asarray(toks)  # sync the warm-up
        t0 = time.perf_counter()
        toks, _ = generate(c, params, prompts, max_len, args.gen,
                           steps=steps)
        np.asarray(toks)
        dt = time.perf_counter() - t0
        n = args.batch * args.gen
        print(f"kv={kv:9s}: {n} tokens in {dt:.2f}s ({n/dt:6.1f} tok/s "
              f"warm); sample: {np.asarray(toks[0, :10])}")


if __name__ == "__main__":
    main()
