"""Registration-as-a-service client demo (``repro.engine.serve``).

Submits N mixed-difficulty volume pairs to a
:class:`~repro.engine.serve.RegistrationScheduler` with staggered arrivals
— the shape of a clinical worklist, where studies trickle in rather than
arriving as one batch — and prints each request's latency as it completes,
plus how many rode a recycled lane (a lane freed mid-flight by another
pair's convergence and immediately respliced).

    python examples/serve_registration.py [--n 8] [--lanes 2] [--stagger 0.2]

Compare against the batch idiom in ``examples/register_volumes.py
--batch``: there every pair waits for the slowest; here each pair's
latency tracks its own difficulty.
"""
import argparse
import sys
import time
from pathlib import Path

try:
    import repro  # noqa: F401
except ModuleNotFoundError:  # src-layout checkout without install
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", type=int, nargs=3, default=(28, 24, 20))
    ap.add_argument("--n", type=int, default=8, help="requests to submit")
    ap.add_argument("--lanes", type=int, default=2,
                    help="in-flight capacity per pyramid level")
    ap.add_argument("--stagger", type=float, default=0.2,
                    help="seconds between request arrivals")
    ap.add_argument("--iters", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.options import RegistrationOptions
    from repro.engine.convergence import ConvergenceConfig
    from repro.engine.serve import RegistrationScheduler
    from repro.launch.serve_registration import mixed_pairs

    # One options object configures the whole service; requests only vary
    # by volume (and, in general, by shape — each shape compiles once).
    options = RegistrationOptions(
        tile=(6, 6, 6), levels=2, iters=args.iters, lr=0.1,
        mode="separable", impl="jnp", grad_impl="xla",
        stop=ConvergenceConfig(tol=2e-3, patience=3))
    sched = RegistrationScheduler(options, lanes=args.lanes, chunk=3,
                                  max_queue=2 * args.n)
    pairs = mixed_pairs(args.n, [tuple(args.shape)], seed=args.seed)

    # warm-up: compile the per-level programs before the timed stream
    f0 = np.zeros(tuple(args.shape), np.float32)
    sched.submit(f0, f0)
    sched.run_until_idle()

    print(f"{args.n} requests, one every {args.stagger:.2f}s, "
          f"{args.lanes} lanes (every 3rd pair is hard)")
    handles, reported = {}, set()
    start = time.perf_counter()
    submitted = 0
    while len(reported) < args.n:
        now = time.perf_counter() - start
        due = min(int(now / args.stagger) + 1, args.n)
        while submitted < due:
            f, m = pairs[submitted]
            handles[submitted] = (sched.submit(f, m), now)
            submitted += 1
        if sched.pending:
            sched.step()
        else:
            time.sleep(args.stagger / 4)
        done_at = time.perf_counter() - start
        for i, (h, t_in) in handles.items():
            if h.done and i not in reported:
                reported.add(i)
                r = h.result()
                tag = " (recycled lane)" if r.recycled else ""
                print(f"  request {i}: {done_at - t_in:5.2f}s latency, "
                      f"steps/level {r.steps}, "
                      f"final loss {r.losses[-1]:.4f}{tag}")

    stats = sched.stats
    print(f"all {stats.completed - 1} + 1 warm-up done in "
          f"{time.perf_counter() - start:.2f}s; "
          f"{stats.recycled} request(s) recycled a mid-flight lane; "
          f"{stats.compiles} compiled stage programs "
          f"({options.levels} levels x {stats.buckets} shape bucket(s))")


if __name__ == "__main__":
    main()
