"""Make the src-layout package importable without installation.

`pip install -e .` is the supported path (and what CI does); this keeps the
bare `python -m pytest` / `PYTHONPATH=src` invocations working on a raw
checkout.
"""
import os
import sys
import tempfile

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Keep test runs from measuring-and-writing the user-global autotune cache
# (~/.cache/repro): tests exercise ffd_register's mode="auto" default.
if "REPRO_AUTOTUNE_CACHE" not in os.environ:
    os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
        tempfile.mkdtemp(prefix="repro-autotune-test-"), "bsi_autotune.json")
