"""Jit-able train / prefill / decode steps shared by the trainer, the server
and the multi-pod dry-run (which lowers exactly these functions)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import dedup_specs, partition_specs
from repro.models import model as M
from repro.optim.optimizer import OptConfig, abstract_opt, opt_init, opt_update

__all__ = [
    "make_train_step", "make_prefill_step", "make_decode_step",
    "init_train_state", "abstract_train_state",
]


def _cast(params, dtype, specs=None):
    """Cast f32 masters to the compute dtype; when sharding specs are given,
    pin the casted copy to the same (FSDP) sharding so XLA all-gathers the
    bf16 copy, not the f32 master (halves FSDP gather bytes)."""
    dt = jnp.dtype(dtype)

    def one(p, s=None):
        if jnp.issubdtype(p.dtype, jnp.floating):
            p = p.astype(dt)
            if s is not None:
                p = jax.lax.with_sharding_constraint(p, s)
        return p

    if specs is None:
        return jax.tree_util.tree_map(one, params)
    return jax.tree_util.tree_map(one, params, specs)


def make_train_step(cfg: ModelConfig, ocfg: OptConfig, rules=None,
                    grad_accum: int = 1, compressor=None):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``grad_accum > 1`` scans over microbatches (sequential, memory-bounded);
    ``compressor`` is an optional gradient transform (e.g. int8 error-feedback
    compression from ``repro.optim.compression``) applied before the update.
    """

    gspecs = (dedup_specs(partition_specs(M.model_schema(cfg), rules))
              if rules is not None else None)

    def loss_of(params, batch):
        return M.loss_fn(_cast(params, cfg.dtype, gspecs), batch, cfg, rules)

    def constrain_grads(grads):
        # Pin gradients to the parameter sharding right after autodiff so
        # GSPMD lowers the data-axis reduction as reduce-scatter (+ sharded
        # optimizer) instead of all-reduce + slice (§Perf iteration 1).
        if gspecs is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, gspecs,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
        )

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            grads = constrain_grads(grads)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]),
                batch,
            )

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum

        new_state = dict(state)
        if compressor is not None:
            grads, new_state["ef"] = compressor(grads, state.get("ef"))
        new_p, new_opt, stats = opt_update(grads, state["opt"], params, ocfg)
        new_state.update(params=new_p, opt=new_opt)
        return new_state, {"loss": loss, **stats}

    return train_step


def make_prefill_step(cfg: ModelConfig, rules=None, max_len=None):
    def prefill_step(params, batch):
        return M.prefill(_cast(params, cfg.dtype), batch, cfg,
                         max_len=max_len, rules=rules)
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules=None):
    def decode_step(params, cache, tokens):
        return M.decode_step(_cast(params, cfg.dtype), cache, tokens, cfg, rules)
    return decode_step


def init_train_state(cfg: ModelConfig, ocfg: OptConfig, seed=0):
    params = M.init_model(cfg, seed=seed, dtype=jnp.float32)
    return {"params": params, "opt": opt_init(params, ocfg)}


def abstract_train_state(cfg: ModelConfig, ocfg: OptConfig):
    params = M.abstract_model(cfg, dtype=jnp.float32)
    return {"params": params, "opt": abstract_opt(params, ocfg)}
