"""Convergence-aware optimisation: the early-stopped ``lax.while_loop``.

The fixed-``iters`` ``lax.scan`` loop (``engine.loop.optimize_scan``) pays
every pair the full BSI budget per pyramid level even after the objective
has plateaued.  Budelmann et al. (PAPERS.md) hit their intra-operative
wall-clock targets precisely by stopping each level when the objective
stalls, and Brunn et al. show the win compounds across pyramid levels —
this module is that stopping rule:

* :class:`ConvergenceConfig` — the ``stop=`` knob threaded through
  ``ffd_register`` / ``affine_register`` / ``register_batch`` (and the
  sharded pipeline): stop a level when the relative loss improvement over a
  ``patience`` window drops below ``tol``, or at ``max_iters``.
* :func:`optimize_until` — the ``lax.while_loop`` counterpart of
  ``optimize_scan``, generic over the ``optimizer=`` registry
  (``engine.optimizer``); :func:`adam_until` is its Adam face, bit-identical
  to the pre-registry loop.  The loop exits as soon as the criterion fires,
  returning ``(params, trace, steps_taken)`` with the trace padded to the
  static ``max_iters`` shape so it stays ``jit``/``vmap``-compatible.

Batched masking comes for free: under ``jax.vmap`` a ``lax.while_loop`` runs
until *every* lane's condition is false, applying each lane's body update
through a per-lane select — converged lanes' carries (params, optimiser
state, trace) freeze at their own stopping point, so a batched lane finishes
with exactly the params its solo run would have produced, and the program
exits as soon as the slowest lane converges.  The wall-clock win is
therefore batch-level: an all-easy (or padded-filler) batch finishes in a
fraction of the budget, while a mixed batch is paced by its slowest pair
(frozen lanes still execute masked BSI work until the exit — SPMD has no
per-lane skipping).  Per-pair savings in full apply on the unbatched
``ffd_register`` / ``affine_register`` path.

Patience semantics with rejected steps (second-order optimisers)
----------------------------------------------------------------
A step "improves" only when it (a) was *accepted* by its optimiser (the
``ok`` flag of ``engine.optimizer.opt_step``) and (b) beats the best loss
seen so far by a relative ``tol``.  A rejected step — an L-BFGS line search
that backtracked to exhaustion, a Gauss-Newton trial the LM damping refused
— leaves the iterate exactly in place and **never counts as progress**: its
``since`` counter still advances, so a lane whose line search collapses
``patience`` times in a row freezes (retiring with its best-so-far params,
which are finite by construction) instead of spinning or NaN-ing.  The
best-so-far restore is unaffected: ``best_p`` only ever absorbs accepted,
strictly-improving iterates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.engine.optimizer import (AdamOptimizer, Objective, adam_update,
                                    init_state, make_objective, opt_step,
                                    resolve_optimizer)

__all__ = ["ConvergenceConfig", "adam_update", "adam_until", "check_stop",
           "optimize_plateau_step", "optimize_until", "plateau_step",
           "level_live"]


@dataclasses.dataclass(frozen=True)
class ConvergenceConfig:
    """Early-stopping rule for a registration level's Adam loop.

    Stop when the relative loss improvement over a ``patience`` window has
    dropped below ``tol`` — concretely, when ``patience`` consecutive steps
    have gone by without any of them beating the best loss seen so far by
    more than ``tol`` (relative: ``(best - loss) / max(|best|, tiny)``) —
    or unconditionally at ``max_iters``.  Tracking the best-so-far rather
    than a fixed lookback makes the rule robust to Adam's transient loss
    bumps: an oscillation only stops the loop if it lasts the whole window.

    ``max_iters=None`` means "inherit the caller's ``iters``" — resolved via
    :meth:`resolve` at the API boundary, so ``stop=ConvergenceConfig()``
    keeps the familiar iteration budget as the ceiling.  Frozen (hashable) on
    purpose: the config is part of every compiled-runner ``lru_cache`` key.
    """

    tol: float = 1e-4
    patience: int = 5
    max_iters: int | None = None

    def __post_init__(self):
        if self.tol < 0:
            raise ValueError(f"tol must be >= 0, got {self.tol}")
        if self.patience < 1:
            raise ValueError(f"patience must be >= 1, got {self.patience}")
        if self.max_iters is not None and self.max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")

    def resolve(self, iters) -> "ConvergenceConfig":
        """A copy with a concrete ``max_iters`` (default: ``iters``)."""
        mx = int(iters) if self.max_iters is None else int(self.max_iters)
        return dataclasses.replace(self, tol=float(self.tol),
                                   patience=int(self.patience), max_iters=mx)


def check_stop(stop, iters):
    """Validate and resolve a ``stop=`` argument (``None`` passes through).

    The single gatekeeper for every ``stop=``-taking entry point
    (``ffd_register`` / ``affine_register`` / ``register_batch`` /
    ``make_adam_runner``): catches the natural mistake of passing the
    tolerance directly (``stop=1e-4``) with a clear ``TypeError`` instead
    of an ``AttributeError``, and pins ``max_iters`` to the caller's
    ``iters`` when unset.
    """
    if stop is None:
        return None
    if not isinstance(stop, ConvergenceConfig):
        raise TypeError(
            f"stop must be a ConvergenceConfig or None, got {stop!r}; "
            "e.g. stop=ConvergenceConfig(tol=1e-4)")
    return stop.resolve(iters)


def optimize_plateau_step(obj, optimizer, k, p, opt, g, loss, since, best,
                          best_p, *, tol, lr):
    """One resumable optimisation step of the plateau-stopped loop.

    The single source of the per-step bookkeeping shared by the
    run-to-completion ``lax.while_loop`` (:func:`optimize_until`) and the
    chunked/resumable serving loop (``engine.serve`` via
    ``engine.batch.compile_level_chunk``): run one ``opt_step`` of the
    registered ``optimizer`` on :class:`~repro.engine.optimizer.Objective`
    ``obj`` (seeded by the carried gradient/loss at ``p``), then fold the
    best-so-far / patience bookkeeping.  Because the whole step state
    travels through the arguments, a caller can run any number of steps,
    hand the state to the host, and resume later — the trajectory is
    step-for-step identical to an uninterrupted loop.

    A step "improves" when it was *accepted* by the optimiser AND beats the
    best loss so far by a relative ``tol`` (see the module docstring on
    rejected steps); ``since`` counts consecutive non-improving steps, and
    the best params ride along so stopping never returns a worse point than
    the loop already visited.

    Returns ``(k+1, p, opt, g, loss, since, best, best_p)`` where ``loss``
    is the post-step loss (the step's trace entry).
    """
    p, opt, g, loss, ok = opt_step(optimizer, obj, k, p, opt, g, loss,
                                   lr=lr)
    gain = (best - loss) / jnp.maximum(jnp.abs(best), jnp.float32(1e-12))
    improved = jnp.logical_and(ok, gain > tol)
    best_p = jnp.where(improved, p, best_p)
    best = jnp.where(improved, loss, best)
    since = jnp.where(improved, 0, since + 1)
    return k + 1, p, opt, g, loss, since, best, best_p


def plateau_step(vg, k, p, m, v, g, since, best, best_p, *, tol, lr,
                 b1=0.9, b2=0.999, eps=1e-8):
    """The Adam spelling of :func:`optimize_plateau_step` (compatibility).

    Kept for callers that still hold the moments as separate ``(m, v)``
    operands; new code should carry the optimiser-state dict.  Returns
    ``(k+1, p, m, v, g, loss, since, best, best_p)`` exactly as before.
    """
    obj = Objective(loss=None, vg=vg)
    spec = AdamOptimizer(b1=b1, b2=b2, eps=eps)
    k1, p, opt, g, loss, since, best, best_p = optimize_plateau_step(
        obj, spec, k, p, {"m": m, "v": v}, g, best, since, best, best_p,
        tol=tol, lr=lr)
    return k1, p, opt["m"], opt["v"], g, loss, since, best, best_p


def level_live(k, since, *, stop, iters=None):
    """Whether a level's loop would take another step — the scheduler's
    per-lane retire-and-refill signal.

    Mirrors :func:`adam_until`'s ``cond`` exactly (``stop`` set), or the
    fixed-``iters`` budget (``stop=None``): a lane is *live* while it has
    budget left and — under a stopping rule — its patience window is open.
    """
    if stop is None:
        return k < int(iters)
    return jnp.logical_and(k < int(stop.max_iters),
                           since < int(stop.patience))


def optimize_until(obj, params, *, optimizer, stop, lr, opt=None):
    """A registered optimiser as a ``lax.while_loop`` that exits on plateau.

    The early-stopped counterpart of ``engine.loop.optimize_scan``: same
    per-step arithmetic (``engine.optimizer.opt_step``), same trace
    convention (``trace[k]`` is the loss after ``k+1`` steps), but the loop
    stops as soon as ``stop.patience`` consecutive steps fail to improve
    the best loss by a relative ``stop.tol`` — or at ``stop.max_iters``.
    Rejected steps (collapsed line search, refused LM trial) count as
    non-improving, so a stuck lane freezes after ``patience`` of them.

    Returns ``(params, trace, steps_taken)``.  ``params`` are the
    best-loss params visited (the start counts: a pair that the optimiser
    can only make worse — e.g. an already-aligned pair, or a ``pad_batch``
    filler lane — stops after ``patience`` steps and keeps its initial
    params instead of the damage).  ``trace`` has the *static* shape
    ``(stop.max_iters,)``: entries up to ``steps_taken`` are the per-step
    losses, the rest are padded with the best (returned) loss, and
    ``trace[-1]`` is always the loss of the returned params — also when the
    budget runs out on a final step that was worse than the best — so the
    result composes with ``jit`` / ``vmap`` / shape-based program caches
    exactly like the fixed-length trace.  ``steps_taken`` is a traced ``int32``
    scalar (per-lane under ``vmap``).

    Under ``vmap``, lanes that converge first freeze (their whole carry —
    params, optimiser state, trace — is select-masked by the batching rule)
    while the loop runs on for the others; the batched program exits when
    the last lane is done.
    """
    if not isinstance(stop, ConvergenceConfig):
        raise TypeError(f"stop must be a ConvergenceConfig, got {stop!r}")
    if stop.max_iters is None:
        raise ValueError(
            "stop.max_iters is unresolved; call stop.resolve(iters) first")
    spec = resolve_optimizer(optimizer)
    max_iters = int(stop.max_iters)
    patience = int(stop.patience)
    tol = jnp.float32(stop.tol)
    opt = init_state(spec, params) if opt is None else opt

    loss0, g0 = obj.vg(params)  # gradient at the initial params seeds step 1
    loss0 = loss0.astype(jnp.float32)

    def cond(carry):
        k = carry[0]
        since = carry[6]
        return jnp.logical_and(k < max_iters, since < patience)

    def body(carry):
        k, p, opt, g, loss, trace, since, best, best_p = carry
        # the shared resumable step; the post-step loss closes trace slot k
        k1, p, opt, g, loss, since, best, best_p = optimize_plateau_step(
            obj, spec, k, p, opt, g, loss, since, best, best_p,
            tol=tol, lr=lr)
        trace = jax.lax.dynamic_update_index_in_dim(trace, loss, k, 0)
        return k1, p, opt, g, loss, trace, since, best, best_p

    carry = (jnp.zeros((), jnp.int32), params, opt, g0, loss0,
             jnp.zeros((max_iters,), jnp.float32),
             jnp.zeros((), jnp.int32), loss0, params)
    out = jax.lax.while_loop(cond, body, carry)
    k, trace, best, best_p = out[0], out[5], out[7], out[8]

    # pad the unreached tail with the best (returned) loss, and pin the
    # last slot to it unconditionally: trace[-1] must be the loss of the
    # params this call returns, also when the budget ran out on a final
    # step that was worse than the best
    trace = jnp.where(jnp.arange(max_iters) < k, trace, best)
    trace = trace.at[-1].set(best)
    return best_p, trace, k


def adam_until(loss_fn, params, *, stop, lr, b1=0.9, b2=0.999, eps=1e-8,
               m=None, v=None):
    """The Adam face of :func:`optimize_until` (the historical API).

    Same update arithmetic as the pre-registry loop (the shared
    :func:`adam_update` through the ``adam`` registry entry), same returns;
    the ``m=``/``v=`` keywords still seed the moments for resumption.
    """
    obj = make_objective(loss_fn)
    opt = None
    if m is not None or v is not None:
        opt = {"m": jnp.zeros_like(params) if m is None else m,
               "v": jnp.zeros_like(params) if v is None else v}
    return optimize_until(obj, params,
                          optimizer=AdamOptimizer(b1=b1, b2=b2, eps=eps),
                          stop=stop, lr=lr, opt=opt)
