"""Convergence-aware optimisation: early-stopped ``lax.while_loop`` Adam.

The fixed-``iters`` ``lax.scan`` loop (``engine.loop.adam_scan``) pays every
pair the full BSI budget per pyramid level even after the objective has
plateaued.  Budelmann et al. (PAPERS.md) hit their intra-operative wall-clock
targets precisely by stopping each level when the objective stalls, and
Brunn et al. show the win compounds across pyramid levels — this module is
that stopping rule:

* :class:`ConvergenceConfig` — the ``stop=`` knob threaded through
  ``ffd_register`` / ``affine_register`` / ``register_batch`` (and the
  sharded pipeline): stop a level when the relative loss improvement over a
  ``patience`` window drops below ``tol``, or at ``max_iters``.
* :func:`adam_until` — the ``lax.while_loop`` counterpart of ``adam_scan``:
  same Adam arithmetic (shared :func:`adam_update` step), but the loop exits
  as soon as the criterion fires, returning ``(params, trace, steps_taken)``
  with the trace padded to the static ``max_iters`` shape so it stays
  ``jit``/``vmap``-compatible.

Batched masking comes for free: under ``jax.vmap`` a ``lax.while_loop`` runs
until *every* lane's condition is false, applying each lane's body update
through a per-lane select — converged lanes' carries (params, moments,
trace) freeze at their own stopping point, so a batched lane finishes with
exactly the params its solo run would have produced, and the program exits
as soon as the slowest lane converges.  The wall-clock win is therefore
batch-level: an all-easy (or padded-filler) batch finishes in a fraction of
the budget, while a mixed batch is paced by its slowest pair (frozen lanes
still execute masked BSI work until the exit — SPMD has no per-lane
skipping).  Per-pair savings in full apply on the unbatched
``ffd_register`` / ``affine_register`` path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["ConvergenceConfig", "adam_update", "adam_until", "check_stop",
           "plateau_step", "level_live"]


@dataclasses.dataclass(frozen=True)
class ConvergenceConfig:
    """Early-stopping rule for a registration level's Adam loop.

    Stop when the relative loss improvement over a ``patience`` window has
    dropped below ``tol`` — concretely, when ``patience`` consecutive steps
    have gone by without any of them beating the best loss seen so far by
    more than ``tol`` (relative: ``(best - loss) / max(|best|, tiny)``) —
    or unconditionally at ``max_iters``.  Tracking the best-so-far rather
    than a fixed lookback makes the rule robust to Adam's transient loss
    bumps: an oscillation only stops the loop if it lasts the whole window.

    ``max_iters=None`` means "inherit the caller's ``iters``" — resolved via
    :meth:`resolve` at the API boundary, so ``stop=ConvergenceConfig()``
    keeps the familiar iteration budget as the ceiling.  Frozen (hashable) on
    purpose: the config is part of every compiled-runner ``lru_cache`` key.
    """

    tol: float = 1e-4
    patience: int = 5
    max_iters: int | None = None

    def __post_init__(self):
        if self.tol < 0:
            raise ValueError(f"tol must be >= 0, got {self.tol}")
        if self.patience < 1:
            raise ValueError(f"patience must be >= 1, got {self.patience}")
        if self.max_iters is not None and self.max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")

    def resolve(self, iters) -> "ConvergenceConfig":
        """A copy with a concrete ``max_iters`` (default: ``iters``)."""
        mx = int(iters) if self.max_iters is None else int(self.max_iters)
        return dataclasses.replace(self, tol=float(self.tol),
                                   patience=int(self.patience), max_iters=mx)


def check_stop(stop, iters):
    """Validate and resolve a ``stop=`` argument (``None`` passes through).

    The single gatekeeper for every ``stop=``-taking entry point
    (``ffd_register`` / ``affine_register`` / ``register_batch`` /
    ``make_adam_runner``): catches the natural mistake of passing the
    tolerance directly (``stop=1e-4``) with a clear ``TypeError`` instead
    of an ``AttributeError``, and pins ``max_iters`` to the caller's
    ``iters`` when unset.
    """
    if stop is None:
        return None
    if not isinstance(stop, ConvergenceConfig):
        raise TypeError(
            f"stop must be a ConvergenceConfig or None, got {stop!r}; "
            "e.g. stop=ConvergenceConfig(tol=1e-4)")
    return stop.resolve(iters)


def adam_update(p, m, v, g, i, *, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam update (bias-corrected with step index ``i``, 1-based).

    The single source of the update arithmetic — shared by the fixed-length
    scan (``engine.loop.adam_scan``) and the early-stopped while loop
    (:func:`adam_until`) so the two trajectories are step-for-step identical
    until the stopping rule fires.
    """
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**i)
    vh = v / (1 - b2**i)
    return p - lr * mh / (jnp.sqrt(vh) + eps), m, v


def plateau_step(vg, k, p, m, v, g, since, best, best_p, *, tol, lr,
                 b1=0.9, b2=0.999, eps=1e-8):
    """One resumable optimisation step of the plateau-stopped Adam loop.

    The single source of the per-step arithmetic shared by the
    run-to-completion ``lax.while_loop`` (:func:`adam_until`) and the
    chunked/resumable serving loop (``engine.serve`` via
    ``engine.batch.compile_level_chunk``): apply the Adam update seeded by
    the carried gradient ``g``, evaluate ``vg`` at the new params, and fold
    the best-so-far / patience bookkeeping.  Because the whole step state
    travels through the arguments, a caller can run any number of steps,
    hand the state to the host, and resume later — the trajectory is
    step-for-step identical to an uninterrupted loop.

    Returns ``(k+1, p, m, v, g, loss, since, best, best_p)`` where ``loss``
    is the post-update loss (the step's trace entry).
    """
    i = (k + 1).astype(jnp.float32)  # 1-based bias-correction index
    p, m, v = adam_update(p, m, v, g, i, lr=lr, b1=b1, b2=b2, eps=eps)
    loss, g = vg(p)
    # a step "improves" when it beats the best loss so far by a relative
    # tol; `since` counts consecutive non-improving steps, and the best
    # params ride along so stopping never returns a worse point than the
    # loop already visited
    gain = (best - loss) / jnp.maximum(jnp.abs(best), jnp.float32(1e-12))
    improved = gain > tol
    best_p = jnp.where(improved, p, best_p)
    best = jnp.where(improved, loss, best)
    since = jnp.where(improved, 0, since + 1)
    return k + 1, p, m, v, g, loss, since, best, best_p


def level_live(k, since, *, stop, iters=None):
    """Whether a level's loop would take another step — the scheduler's
    per-lane retire-and-refill signal.

    Mirrors :func:`adam_until`'s ``cond`` exactly (``stop`` set), or the
    fixed-``iters`` budget (``stop=None``): a lane is *live* while it has
    budget left and — under a stopping rule — its patience window is open.
    """
    if stop is None:
        return k < int(iters)
    return jnp.logical_and(k < int(stop.max_iters),
                           since < int(stop.patience))


def adam_until(loss_fn, params, *, stop, lr, b1=0.9, b2=0.999, eps=1e-8,
               m=None, v=None):
    """Adam as a ``lax.while_loop`` that exits when the loss plateaus.

    The early-stopped counterpart of ``engine.loop.adam_scan``: same update
    arithmetic (:func:`adam_update`), same trace convention (``trace[k]`` is
    the loss after ``k+1`` updates), but the loop stops as soon as
    ``stop.patience`` consecutive steps fail to improve the best loss by a
    relative ``stop.tol`` — or at ``stop.max_iters``.

    Returns ``(params, trace, steps_taken)``.  ``params`` are the
    best-loss params visited (the start counts: a pair that the optimiser
    can only make worse — e.g. an already-aligned pair, or a ``pad_batch``
    filler lane — stops after ``patience`` steps and keeps its initial
    params instead of the damage).  ``trace`` has the *static* shape
    ``(stop.max_iters,)``: entries up to ``steps_taken`` are the per-step
    losses, the rest are padded with the best (returned) loss, and
    ``trace[-1]`` is always the loss of the returned params — also when the
    budget runs out on a final step that was worse than the best — so the
    result composes with ``jit`` / ``vmap`` / shape-based program caches
    exactly like the fixed-length trace.  ``steps_taken`` is a traced ``int32``
    scalar (per-lane under ``vmap``).

    Under ``vmap``, lanes that converge first freeze (their whole carry is
    select-masked by the batching rule) while the loop runs on for the
    others; the batched program exits when the last lane is done.
    """
    if not isinstance(stop, ConvergenceConfig):
        raise TypeError(f"stop must be a ConvergenceConfig, got {stop!r}")
    if stop.max_iters is None:
        raise ValueError(
            "stop.max_iters is unresolved; call stop.resolve(iters) first")
    max_iters = int(stop.max_iters)
    patience = int(stop.patience)
    tol = jnp.float32(stop.tol)
    m = jnp.zeros_like(params) if m is None else m
    v = jnp.zeros_like(params) if v is None else v

    vg = jax.value_and_grad(loss_fn)
    loss0, g0 = vg(params)  # gradient at the initial params seeds step 1

    def cond(carry):
        k = carry[0]
        since = carry[6]
        return jnp.logical_and(k < max_iters, since < patience)

    def body(carry):
        k, p, m, v, g, trace, since, best, best_p = carry
        # the shared resumable step (see plateau_step); the post-update loss
        # closes slot k of the trace
        k1, p, m, v, g, loss, since, best, best_p = plateau_step(
            vg, k, p, m, v, g, since, best, best_p,
            tol=tol, lr=lr, b1=b1, b2=b2, eps=eps)
        trace = jax.lax.dynamic_update_index_in_dim(trace, loss, k, 0)
        return k1, p, m, v, g, trace, since, best, best_p

    carry = (jnp.zeros((), jnp.int32), params, m, v, g0,
             jnp.zeros((max_iters,), jnp.float32),
             jnp.zeros((), jnp.int32), loss0.astype(jnp.float32), params)
    out = jax.lax.while_loop(cond, body, carry)
    k, trace, best, best_p = out[0], out[5], out[7], out[8]

    # pad the unreached tail with the best (returned) loss, and pin the
    # last slot to it unconditionally: trace[-1] must be the loss of the
    # params this call returns, also when the budget ran out on a final
    # step that was worse than the best
    trace = jnp.where(jnp.arange(max_iters) < k, trace, best)
    trace = trace.at[-1].set(best)
    return best_p, trace, k
