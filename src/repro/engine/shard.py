"""Mesh-sharded batched registration — data-parallel serving over a pod.

``engine.batch.register_batch`` compiles one ``jit(vmap)`` program pinned to
a single device; this module places that program's batch axis over a
``jax.sharding.Mesh`` instead, so a pod of N accelerators serves N shards of
a registration batch concurrently (Budelmann et al. and Brunn et al. — see
PAPERS.md — both get intra-operative latencies from scaling the *loop*
across devices, not just the kernel).

The layout comes from ``repro.distributed.sharding.REGISTRATION_RULES``:
batch → the mesh's data axes, everything per-pair (volume and grid geometry,
the displacement channel, optimiser state, loss traces) replicated per
shard.
``sharded_pipeline`` re-states that placement with
``with_sharding_constraint`` at every pyramid level and ``lax.scan``
boundary, so GSPMD never has a reason to gather the batch axis mid-loop.

Non-divisible batches are padded (repeating the last pair) up to the batch
multiple of the mesh; ``register_batch`` strips the pad rows on return.
Callers driving ``compile_sharded_batch`` / ``sharded_pipeline`` directly
get the *padded* outputs and can mask the synthetic rows with
``batch_mask``.  ``make_registration_mesh()`` works on real accelerators and
on fake CPU devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``
exported before jax is imported), which is how CI exercises this path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.core import ffd
from repro.distributed.sharding import REGISTRATION_RULES
from repro.engine.loop import optimize_scan

__all__ = [
    "VOLUME_AXES",
    "GRID_AXES",
    "LOSS_AXES",
    "make_registration_mesh",
    "batch_multiple",
    "pad_batch",
    "batch_mask",
    "lane_sharding",
    "sharded_pipeline",
    "compile_sharded_batch",
]

# Logical axes (REGISTRATION_RULES names) of the three result trees.
VOLUME_AXES = ("batch", "vol_x", "vol_y", "vol_z")
GRID_AXES = ("batch", "grid_x", "grid_y", "grid_z", "disp")
LOSS_AXES = ("batch", "level")


def make_registration_mesh(num_devices=None, *, devices=None):
    """A 1-D ``("data",)`` mesh over the local devices (default: all).

    The axis is named ``"data"`` because that is the name REGISTRATION_RULES
    (and therefore ``batch_multiple`` / ``compile_sharded_batch``) binds the
    batch axis to.  Works identically on a real accelerator pod and on fake
    host devices: export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before*
    importing jax to rehearse the 8-way layout on a laptop or in CI.
    """
    devs = tuple(devices) if devices is not None else tuple(jax.devices())
    n = len(devs) if num_devices is None else int(num_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"need {n} devices for a registration mesh, have {len(devs)}; "
            "on CPU export XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(n, 2)} before importing jax to fake a pod")
    return jax.make_mesh((n,), ("data",), devices=devs[:n])


def batch_multiple(mesh) -> int:
    """Shard count of the batch axis — what batch sizes must pad up to."""
    axes = REGISTRATION_RULES(mesh.axis_names)["batch"]
    axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
    return math.prod(mesh.shape[a] for a in axes if a in mesh.shape) or 1


def pad_batch(x, multiple):
    """Pad the leading axis up to ``multiple`` by repeating the last entry.

    Returns ``(padded, orig_b)``; callers strip results back to ``orig_b``
    rows (see ``batch_mask`` for the validity mask).  Repeating a real pair
    (rather than zero-filling) keeps the padded rows numerically ordinary —
    no similarity term ever sees a degenerate all-zero volume.
    """
    b = x.shape[0]
    if b == 0:
        # x[-1:] on an empty leading axis repeats nothing — padding would
        # silently return an empty array and the batched program would fail
        # much later with an opaque shape error
        raise ValueError(
            "pad_batch got an empty batch (leading axis 0); there is no "
            "last entry to repeat — supply at least one pair")
    pad = (-b) % int(multiple)
    if pad:
        x = jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)], axis=0)
    return x, b


def batch_mask(orig_b, padded_b):
    """Boolean ``(padded_b,)`` mask: True for real rows, False for padding.

    ``register_batch`` strips pad rows itself; this is for callers that use
    ``compile_sharded_batch``/``sharded_pipeline`` directly and therefore
    hold padded outputs (e.g. to exclude synthetic rows from aggregate
    loss/quality statistics without a host round-trip).
    """
    return jnp.arange(int(padded_b)) < int(orig_b)


def lane_sharding(mesh):
    """The batch-over-data ``NamedSharding`` for a leading lane/batch axis.

    Used as a pytree-prefix placement: ``jax.device_put(state,
    lane_sharding(mesh))`` shards every leaf of a lane-array state dict
    (``engine.batch.compile_level_chunk``'s operand) along its leading lane
    axis, replicating everything per-lane — the same placement
    ``REGISTRATION_RULES`` gives ``register_batch``'s batch axis, so the
    serving scheduler's chunked loop and the monolithic sharded pipeline
    distribute identically.  Lane widths should be a multiple of
    ``batch_multiple(mesh)`` for an even split.
    """
    return NamedSharding(mesh, REGISTRATION_RULES(mesh.axis_names).spec(
        ("batch",)))


def sharded_pipeline(fixed, moving, *, tile, levels, iters, lr,
                     bending_weight, mode, impl, similarity, mesh,
                     grad_impl="xla", compute_dtype=None,
                     transform="displacement", regularizer="none",
                     rules=None, stop=None, fused="off", optimizer="adam"):
    """Batched multi-level FFD with explicit sharding constraints.

    Same math as ``jax.vmap(engine.batch.ffd_pipeline)`` — the pyramid, the
    per-level ``ffd_level_objective`` + ``optimize_scan``, the final warp —
    but batch-first, with the REGISTRATION_RULES placement re-asserted on
    the pyramid, on the control grid entering and leaving every scan level,
    and on the outputs.  Returns ``(warped, phi, losses)`` with shapes
    ``(B, X, Y, Z)``, ``(B, *grid, 3)``, ``(B, levels)``.

    ``optimizer`` (name or ``engine.optimizer`` spec) picks the per-level
    loop; every registered step is pure per-pair arithmetic — bounded inner
    loops, validity masks, no data-dependent shapes — so the L-BFGS history
    window and the Gauss-Newton CG solve shard exactly like the Adam
    moments (per-pair state replicated along the batch axis, no cross-
    device traffic beyond the loop predicate's all-reduce).

    ``stop`` (a resolved ``ConvergenceConfig``) swaps each level's scan for
    the early-stopped ``lax.while_loop``
    (``engine.convergence.optimize_until``) — the loop's lane masking is
    per-pair arithmetic too, so it shards exactly like the scan — and
    appends a ``(B, levels)`` steps array to the return.
    """
    from repro.engine.batch import ffd_level_objective
    from repro.engine.convergence import optimize_until

    rules = REGISTRATION_RULES(mesh.axis_names) if rules is None else rules

    def cons(x, axes):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, rules.spec(axes)))

    pyramid = [(fixed, moving)]
    for _ in range(levels - 1):
        f, m = pyramid[-1]
        pyramid.append((jax.vmap(ffd.downsample2)(f),
                        jax.vmap(ffd.downsample2)(m)))
    pyramid = [(cons(f, VOLUME_AXES), cons(m, VOLUME_AXES))
               for f, m in pyramid[::-1]]  # coarse -> fine

    phi = None
    finals = []
    steps = []
    for f, m in pyramid:
        gshape = ffd.grid_shape_for_volume(f.shape[1:], tile)
        if phi is None:
            phi = jnp.zeros((f.shape[0],) + gshape + (3,), jnp.float32)
        else:
            phi = jax.vmap(lambda p, g=gshape: ffd.upsample_grid(p, g))(phi)
        phi = cons(phi, GRID_AXES)

        def level(f1, m1, p1):
            obj = ffd_level_objective(
                f1, m1, tile=tile, bending_weight=bending_weight,
                mode=mode, impl=impl, grad_impl=grad_impl,
                compute_dtype=compute_dtype, similarity=similarity,
                transform=transform, regularizer=regularizer,
                fused=fused)
            if stop is None:
                return optimize_scan(obj, p1, optimizer=optimizer,
                                     iters=iters, lr=lr)
            return optimize_until(obj, p1, optimizer=optimizer, stop=stop,
                                  lr=lr)

        out = jax.vmap(level)(f, m, phi)
        phi, trace = out[:2]
        if stop is not None:
            steps.append(out[2])
        phi = cons(phi, GRID_AXES)
        finals.append(trace[:, -1])

    def finish(m1, p1):
        from repro.core.transform import dense_displacement

        disp = dense_displacement(transform, p1, tile, m1.shape, mode=mode,
                                  impl=impl, grad_impl=grad_impl)
        return ffd.warp_volume(m1, disp)

    warped = cons(jax.vmap(finish)(moving, phi), VOLUME_AXES)
    losses = cons(jnp.stack(finals, axis=1), LOSS_AXES)
    if stop is None:
        return warped, phi, losses
    return warped, phi, losses, cons(jnp.stack(steps, axis=1), LOSS_AXES)


def compile_sharded_batch(mesh, tile, levels, iters, lr,
                          bending_weight, mode, impl, similarity,
                          grad_impl="xla", compute_dtype=None,
                          transform="displacement", regularizer="none",
                          stop=None, fused="off", optimizer="adam"):
    """Build the jitted sharded pipeline for one (mesh, configuration).

    Uncached by design: ``engine.batch._compiled_batch`` is the single
    program cache (its key includes ``mesh`` — ``jax.sharding.Mesh`` hashes
    by devices + axis names, so two meshes over the same pod share a compile
    and a re-deployed mesh gets its own).  ``in_shardings`` place the
    incoming stacks batch-over-data (uncommitted host arrays are transferred
    shard-by-shard, never materialised whole on one device);
    ``out_shardings`` keep results distributed for the caller.
    """
    rules = REGISTRATION_RULES(mesh.axis_names)
    vol_sh = NamedSharding(mesh, rules.spec(VOLUME_AXES))
    loss_sh = NamedSharding(mesh, rules.spec(LOSS_AXES))
    out_sh = (vol_sh, NamedSharding(mesh, rules.spec(GRID_AXES)), loss_sh)
    if stop is not None:  # the (B, levels) steps array shards like losses
        out_sh = out_sh + (loss_sh,)

    def batched(F, M):
        return sharded_pipeline(
            F, M, tile=tile, levels=levels, iters=iters, lr=lr,
            bending_weight=bending_weight, mode=mode, impl=impl,
            grad_impl=grad_impl, compute_dtype=compute_dtype,
            similarity=similarity, transform=transform,
            regularizer=regularizer, mesh=mesh, rules=rules, stop=stop,
            fused=fused, optimizer=optimizer)

    return jax.jit(batched, in_shardings=(vol_sh, vol_sh),
                   out_shardings=out_sh)
