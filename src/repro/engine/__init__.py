"""Batched, device-resident registration engine.

The workload-scale layer over ``repro.core``: scan-compiled optimisation
loops (``engine.loop``), whole-pipeline batching via ``vmap`` so N volume
pairs register in one jitted program (``engine.batch.register_batch``), and
a benchmark-and-cache autotuner that picks the fastest BSI form per
configuration instead of hardcoded defaults (``engine.autotune``).
"""
from repro.engine.autotune import (BsiChoice, autotune_bsi,
                                   default_candidates, resolve_bsi)
from repro.engine.batch import (BatchRegistrationResult, ffd_pipeline,
                                register_batch)
from repro.engine.loop import adam_scan, make_adam_runner

__all__ = [
    "BsiChoice",
    "autotune_bsi",
    "default_candidates",
    "resolve_bsi",
    "BatchRegistrationResult",
    "ffd_pipeline",
    "register_batch",
    "adam_scan",
    "make_adam_runner",
]
