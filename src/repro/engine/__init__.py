"""Batched, device-resident registration engine.

The workload-scale layer over ``repro.core``: scan-compiled optimisation
loops (``engine.loop``), whole-pipeline batching via ``vmap`` so N volume
pairs register in one jitted program (``engine.batch.register_batch``), a
benchmark-and-cache autotuner that picks the fastest BSI form per
configuration instead of hardcoded defaults (``engine.autotune``), and
mesh-sharded data-parallel serving that places the batch axis over a device
pod (``engine.shard``, via ``register_batch(..., mesh=...)``).
"""
from repro.engine.autotune import (BsiChoice, autotune_bsi,
                                   default_candidates, default_grad_impls,
                                   resolve_bsi)
from repro.engine.batch import (BatchRegistrationResult, ffd_pipeline,
                                register_batch)
from repro.engine.loop import adam_scan, make_adam_runner
from repro.engine.shard import make_registration_mesh, sharded_pipeline

__all__ = [
    "BsiChoice",
    "autotune_bsi",
    "default_candidates",
    "default_grad_impls",
    "resolve_bsi",
    "BatchRegistrationResult",
    "ffd_pipeline",
    "register_batch",
    "adam_scan",
    "make_adam_runner",
    "make_registration_mesh",
    "sharded_pipeline",
]
