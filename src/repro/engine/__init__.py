"""Batched, device-resident registration engine.

The workload-scale layer over ``repro.core``: scan-compiled optimisation
loops (``engine.loop``) over a pluggable ``optimizer=`` registry — Adam by
default, second-order L-BFGS and Gauss-Newton entries for hard pairs
(``engine.optimizer``) — whole-pipeline batching via ``vmap`` so N volume
pairs register in one jitted program (``engine.batch.register_batch``), a
benchmark-and-cache autotuner that picks the fastest BSI form per
configuration instead of hardcoded defaults (``engine.autotune``),
mesh-sharded data-parallel serving that places the batch axis over a device
pod (``engine.shard``, via ``register_batch(..., mesh=...)``),
convergence-aware early stopping so easy pairs stop paying for BSI work
they no longer need (``engine.convergence``, via ``stop=``), and a
continuous-batching request scheduler that splices queued pairs into lanes
freed by the convergence mask (``engine.serve``).
"""
from repro.engine.autotune import (BsiChoice, autotune_bsi,
                                   default_candidates, default_grad_impls,
                                   resolve_bsi, resolve_options)
from repro.engine.batch import (BatchRegistrationResult, ffd_pipeline,
                                register_batch)
from repro.engine.convergence import (ConvergenceConfig, adam_until,
                                      optimize_until)
from repro.engine.loop import adam_scan, make_adam_runner, optimize_scan
from repro.engine.optimizer import (OPTIMIZERS, AdamOptimizer,
                                    GaussNewtonOptimizer, LbfgsOptimizer,
                                    Objective, adam, available_optimizers,
                                    gauss_newton, lbfgs, make_objective,
                                    optimizer_token, resolve_optimizer)
from repro.engine.serve import (AsyncRegistrationService, QueueFull,
                                RegistrationScheduler, RegistrationTimeout,
                                ServeResult, ServeStats)
from repro.engine.shard import make_registration_mesh, sharded_pipeline

__all__ = [
    "BsiChoice",
    "autotune_bsi",
    "default_candidates",
    "default_grad_impls",
    "resolve_bsi",
    "resolve_options",
    "BatchRegistrationResult",
    "ffd_pipeline",
    "register_batch",
    "ConvergenceConfig",
    "adam_until",
    "optimize_until",
    "adam_scan",
    "make_adam_runner",
    "optimize_scan",
    "OPTIMIZERS",
    "AdamOptimizer",
    "GaussNewtonOptimizer",
    "LbfgsOptimizer",
    "Objective",
    "adam",
    "available_optimizers",
    "gauss_newton",
    "lbfgs",
    "make_objective",
    "optimizer_token",
    "resolve_optimizer",
    "AsyncRegistrationService",
    "QueueFull",
    "RegistrationScheduler",
    "RegistrationTimeout",
    "ServeResult",
    "ServeStats",
    "make_registration_mesh",
    "sharded_pipeline",
]
