"""Batched, device-resident registration engine.

The workload-scale layer over ``repro.core``: scan-compiled optimisation
loops (``engine.loop``), whole-pipeline batching via ``vmap`` so N volume
pairs register in one jitted program (``engine.batch.register_batch``), a
benchmark-and-cache autotuner that picks the fastest BSI form per
configuration instead of hardcoded defaults (``engine.autotune``),
mesh-sharded data-parallel serving that places the batch axis over a device
pod (``engine.shard``, via ``register_batch(..., mesh=...)``), and
convergence-aware early stopping so easy pairs stop paying for BSI work
they no longer need (``engine.convergence``, via ``stop=``).
"""
from repro.engine.autotune import (BsiChoice, autotune_bsi,
                                   default_candidates, default_grad_impls,
                                   resolve_bsi)
from repro.engine.batch import (BatchRegistrationResult, ffd_pipeline,
                                register_batch)
from repro.engine.convergence import ConvergenceConfig, adam_until
from repro.engine.loop import adam_scan, make_adam_runner
from repro.engine.shard import make_registration_mesh, sharded_pipeline

__all__ = [
    "BsiChoice",
    "autotune_bsi",
    "default_candidates",
    "default_grad_impls",
    "resolve_bsi",
    "BatchRegistrationResult",
    "ffd_pipeline",
    "register_batch",
    "ConvergenceConfig",
    "adam_until",
    "adam_scan",
    "make_adam_runner",
    "make_registration_mesh",
    "sharded_pipeline",
]
