"""Autotuner: pick the fastest BSI (mode, impl) for a (grid_shape, tile).

The paper's comparison matrix (§5) has no single winner: which algorithm
form is fastest depends on tile size, grid size and the backend (the
separable tensor-contraction form wins where matmul units dominate; the
lerp form wins where FMA-bound).  Instead of hardcoding ``mode=`` / ``impl=``
defaults in every caller, the engine benchmarks the available forms for the
configuration actually being registered and caches the winner:

* in-process memory cache, keyed by ``backend|grid|tile|channels``;
* an optional JSON disk cache (``$REPRO_AUTOTUNE_CACHE`` or
  ``~/.cache/repro/bsi_autotune.json``) so repeated process launches —
  benchmark runs, serving replicas — skip the measurement entirely.

The disk file is versioned (``SCHEMA_VERSION``): entries live under a
``{"__schema__": N, "entries": {...}}`` wrapper, and a file from another
schema — e.g. a pre-fused-axis cache — reads as a clean miss (re-benchmark
and rewrite), never a ``KeyError`` or a silently mis-dispatched choice.

Callers go through :func:`resolve_bsi`, which passes explicit choices
through untouched and only tunes the ``"auto"`` axes;
:func:`resolve_options` additionally races the fused level step
(``core.ffd.fused_warp_loss``) against the unfused winner when
``options.fused == "auto"`` (:func:`autotune_fused`).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interpolate import GRAD_IMPLS, MODES, interpolate
from repro.core.similarity import resolve_similarity, similarity_token
from repro.core.transform import (VelocityTransform, resolve_transform,
                                  scaling_and_squaring, transform_token)
from repro.kernels.ops import PALLAS_MODES

__all__ = ["BsiChoice", "SCHEMA_VERSION", "autotune_bsi", "autotune_fused",
           "resolve_bsi", "resolve_options", "default_candidates",
           "default_grad_impls", "default_cache_path"]

JNP_CANDIDATES = tuple((m, "jnp") for m in sorted(MODES))
PALLAS_CANDIDATES = tuple((m, "pallas") for m in PALLAS_MODES)

# Disk-cache schema.  v2 added the fused level-step axis (BsiChoice.fused +
# the "|fused|" race entries) and moved entries under the versioned wrapper;
# v1 files (flat {key: choice} dicts) predate it and read as a clean miss.
# v3 added the matmul mode + the "matmul" adjoint to the candidate space:
# pre-matmul (v2) files pinned winners measured without the MXU form in the
# race, so they re-benchmark as a clean miss rather than silently excluding
# the new candidates.
SCHEMA_VERSION = 3


@dataclasses.dataclass(frozen=True)
class BsiChoice:
    mode: str
    impl: str
    us_per_call: float
    # adjoint implementation ("xla" = plain autodiff — the pre-custom-VJP
    # behaviour, and what legacy cache entries decode to)
    grad_impl: str = "xla"
    # fused level step ("on" = core.ffd.fused_warp_loss won the race for
    # this configuration; entries written by autotune_fused only)
    fused: str = "off"


_MEM_CACHE: dict = {}


def default_cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "bsi_autotune.json")


def default_candidates():
    """Forms worth benchmarking on the current backend.

    On CPU the Pallas kernels only run under ``interpret=True`` — a
    correctness path, orders of magnitude slower than the jnp forms — so
    they are excluded unless ``REPRO_AUTOTUNE_PALLAS=1`` forces them in.
    """
    cands = list(JNP_CANDIDATES)
    if jax.default_backend() != "cpu" or os.environ.get("REPRO_AUTOTUNE_PALLAS"):
        cands += list(PALLAS_CANDIDATES)
    return tuple(cands)


def default_grad_impls():
    """Adjoint implementations worth benchmarking on the current backend.

    ``xla`` (plain autodiff) and ``jnp`` (the analytic separable-transpose
    custom VJP) everywhere; the Pallas adjoint kernels — ``pallas`` (the
    separable sweeps) and ``matmul`` (the transposed MXU contraction) —
    join off-CPU (or with ``REPRO_AUTOTUNE_PALLAS=1``), same reasoning as
    :func:`default_candidates`.
    """
    impls = ["xla", "jnp"]
    if jax.default_backend() != "cpu" or os.environ.get("REPRO_AUTOTUNE_PALLAS"):
        impls += ["pallas", "matmul"]
    return tuple(impls)


def _key(grid_shape, tile, channels) -> str:
    g = "x".join(map(str, grid_shape))
    t = "x".join(map(str, tile))
    return f"{jax.default_backend()}|g{g}|t{t}|c{channels}"


def _load_disk(path) -> dict:
    """Best-effort read: a corrupt/stale/wrong-shape cache is a miss.

    A half-written or hand-edited ``bsi_autotune.json`` must trigger a clean
    re-benchmark (which then rewrites the file), never an unhandled
    ``JSONDecodeError`` — and so must a file written by another
    ``SCHEMA_VERSION`` (e.g. a pre-fused flat ``{key: choice}`` cache),
    whose entries would otherwise decode with the new axes silently filled
    by defaults measured under a different dispatch.
    """
    try:
        with open(path) as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict) or data.get("__schema__") != SCHEMA_VERSION:
        return {}
    entries = data.get("entries")
    return entries if isinstance(entries, dict) else {}


def _parse_choice(hit):
    """A malformed cache entry (missing/mistyped fields) is a miss."""
    try:
        choice = BsiChoice(str(hit["mode"]), str(hit["impl"]),
                           float(hit["us_per_call"]),
                           str(hit.get("grad_impl", "xla")),
                           str(hit.get("fused", "off")))
    except (KeyError, TypeError, ValueError, AttributeError):
        return None
    return choice if choice.fused in ("on", "off") else None


def _store_disk(path, key, choice) -> None:
    entries = _load_disk(path)
    entries[key] = dataclasses.asdict(choice)
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            json.dump({"__schema__": SCHEMA_VERSION, "entries": entries},
                      fh, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic: concurrent tuners never corrupt it
    except OSError:
        pass  # cache is best-effort; tuning still returned in-process


def autotune_bsi(grid_shape, tile, channels=3, *, candidates=None, reps=3,
                 cache_path=None, use_cache=True, measure_grad=False,
                 similarity=None, grad_impls=None, compute_dtype=None,
                 transform=None, stop=None, optimizer=None) -> BsiChoice:
    """Benchmark the candidate BSI forms and return (and cache) the winner.

    Args:
      grid_shape: stored control-grid dims ``(Tx+3, Ty+3, Tz+3)``.
      tile: control-point spacing ``(dx, dy, dz)``.
      channels: trailing channel count of the grid (3 for displacement).
      candidates: optional ``((mode, impl), ...)`` override — or, with
        ``measure_grad``, ``((mode, impl, grad_impl), ...)`` triples.
      reps: timed repetitions per candidate (after a compile+warmup call).
      cache_path: JSON cache location (``None`` -> :func:`default_cache_path`).
      use_cache: bypass both caches when False (always re-measure).
      measure_grad: time forward+backward (the registration loop's workload)
        instead of the forward alone.  Candidates that cannot differentiate
        (Pallas forwards under the plain-autodiff ``"xla"`` adjoint) are
        excluded automatically.
      similarity: optional similarity name/callable.  With ``measure_grad``,
        the timed objective becomes warp + that similarity on top of the BSI
        expansion — the measurement (and its cache entry) is per-similarity,
        since e.g. NMI's histogram backward changes the workload mix XLA
        fuses around each BSI form.
      grad_impls: adjoint implementations to cross ``(mode, impl)`` pairs
        with under ``measure_grad`` (see ``interpolate``'s ``grad_impl``).
        Defaults to ``("xla",)`` — the historical forward-only enumeration —
        so forward-only and legacy callers are unaffected; the engine passes
        :func:`default_grad_impls` to tune the full (fwd x adjoint) matrix.
      compute_dtype: optional reduced compute dtype (e.g. ``"bfloat16"``).
        The measured workload runs the BSI expansion (and warp) in that
        dtype — what the registration loop will actually execute — and the
        cache entry is per-dtype, so fp32 and bf16 callers never share a
        possibly-differently-ranked winner.
      transform: optional transform name/spec (``repro.core.transform``).
        With the velocity transform (and ``measure_grad`` + ``similarity``),
        the timed objective integrates the expansion by scaling and squaring
        before the warp — the velocity loop's actual per-step workload,
        whose composition chain changes what XLA fuses around each BSI form.
        The cache entry gains a ``|tf=...`` token only for non-displacement
        transforms, so existing displacement entries stay valid.
      stop: must stay ``None``.  The timing workload is one fixed
        forward+backward step — early stopping (``ConvergenceConfig``)
        changes how *many* steps a given pair runs, never the per-step cost
        a kernel choice should be ranked on, and a data-dependent loop
        length would make the measurement (and its cache entry) depend on
        the synthetic pair's convergence.  Engine callers resolve ``stop``
        outside the tuner; passing it here is a usage error.
      optimizer: optional optimiser name/spec (``repro.engine.optimizer``).
        The timed workload stays the one forward+backward BSI step — it is
        the per-step kernel work every registered optimiser shares (L-BFGS's
        two-loop and Gauss-Newton's CG ride on the same expansion/adjoint
        kernels) — but the cache entry gains an ``|opt=...`` token for
        non-default optimisers, so a second-order run never silently reuses
        (or overwrites) a winner recorded under a different step
        composition.  The default Adam adds no token: pre-registry disk
        cache entries stay valid without a ``SCHEMA_VERSION`` bump.
    """
    if stop is not None:
        raise ValueError(
            "autotune_bsi times a fixed-iteration workload; stop= must be "
            "None (early stopping changes step count, not per-step cost)")
    grid_shape = tuple(int(g) for g in grid_shape)
    tile = tuple(int(t) for t in tile)
    channels = int(channels)
    compute_dtype = (jnp.dtype(compute_dtype).name
                     if compute_dtype is not None else None)
    tspec = resolve_transform(transform) if transform is not None else None
    velocity = isinstance(tspec, VelocityTransform)
    opt_token = None
    if optimizer is not None:
        from repro.engine.optimizer import optimizer_token

        tok = optimizer_token(optimizer)
        opt_token = None if tok == "adam" else tok
    cands = (default_candidates() if candidates is None
             else tuple(candidates))
    gis = ("xla",) if grad_impls is None else tuple(grad_impls)
    if measure_grad:
        # cross (mode, impl) pairs with the adjoint axis; explicit triples
        # pass through as-is
        cands = tuple(c if len(c) == 3 else c + (gi,)
                      for c in cands for gi in (gis if len(c) == 2 else ("",)))
    else:
        cands = tuple(c[:2] for c in cands)
    # the key names everything that can change the measurement
    key = (_key(grid_shape, tile, channels)
           + ("|grad" if measure_grad else "")
           + ("" if similarity is None
              else f"|sim={similarity_token(similarity)}")
           + ("" if compute_dtype is None else f"|cd={compute_dtype}")
           + (f"|tf={transform_token(tspec)}" if velocity else "")
           + ("" if opt_token is None else f"|opt={opt_token}")
           + "|" + ",".join("/".join(c) for c in cands))
    cache_path = default_cache_path() if cache_path is None else cache_path
    mem_key = (cache_path, key)

    if use_cache and mem_key in _MEM_CACHE:
        return _MEM_CACHE[mem_key]
    if use_cache:
        hit = _load_disk(cache_path).get(key)
        choice = _parse_choice(hit) if hit else None
        if choice is not None:
            _MEM_CACHE[mem_key] = choice
            return choice

    # Measure on ONE device explicitly.  Mesh-sharded serving (engine.shard)
    # is pure data parallelism — each device runs the whole per-pair loop —
    # so the single-device measurement *is* the per-shard workload, and
    # pinning keeps the timing stable when the process holds a pod (or
    # XLA_FLAGS-faked multi-device) context.
    dev = jax.local_devices()[0]
    rng = np.random.default_rng(0)
    phi = jax.device_put(
        jnp.asarray(rng.standard_normal(grid_shape + (channels,)),
                    jnp.float32), dev)
    objective = None
    if measure_grad and similarity is not None:
        _, sim_fn = resolve_similarity(similarity)
        dense_shape = tuple((g - 3) * t for g, t in zip(grid_shape, tile))
        fix = jax.device_put(jnp.asarray(rng.random(dense_shape),
                                         jnp.float32), dev)
        if channels == 3:
            # the registration loop's objective: warp a volume by the
            # expanded field, then score it against a fixed volume
            from repro.core.ffd import warp_volume

            mov = jax.device_put(jnp.asarray(rng.random(dense_shape),
                                             jnp.float32), dev)

            def objective(out):
                if velocity:
                    out = scaling_and_squaring(out, tspec.squarings)
                warped = warp_volume(mov, out, compute_dtype=compute_dtype)
                return sim_fn(warped.astype(fix.dtype), fix)
        else:

            def objective(out):
                return sim_fn(out[..., 0].astype(fix.dtype), fix)

    best = None
    for cand in cands:
        mode, impl = cand[0], cand[1]
        gi = cand[2] if len(cand) == 3 else "xla"

        def fwd(p, mode=mode, impl=impl, gi=gi):
            return interpolate(p, tile, mode=mode, impl=impl, grad_impl=gi,
                               dtype=compute_dtype)

        if measure_grad and objective is not None:
            fn = jax.jit(jax.grad(lambda p: objective(fwd(p))))
        elif measure_grad:
            fn = jax.jit(jax.grad(lambda p: fwd(p).sum()))
        else:
            fn = jax.jit(fwd)  # consumers always run the form under jit
        try:
            jax.block_until_ready(fn(phi))  # compile + warmup
        except Exception:
            continue  # candidate unavailable on this backend/workload
        times = []
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(phi))
            times.append(time.perf_counter() - t0)
        us = float(np.median(times) * 1e6)
        if best is None or us < best.us_per_call:
            best = BsiChoice(mode, impl, us, gi)
    if best is None:
        raise RuntimeError(
            f"no BSI candidate succeeded for grid={grid_shape} tile={tile} "
            f"candidates={cands}")

    if use_cache:
        _MEM_CACHE[mem_key] = best
        _store_disk(cache_path, key, best)
    return best


def autotune_fused(grid_shape, tile, vol_shape, *, base, similarity,
                   compute_dtype=None, reps=3, cache_path=None,
                   use_cache=True) -> BsiChoice:
    """Race the fused level step against the unfused winner ``base``.

    ``base`` is the already-resolved unfused :class:`BsiChoice` (concrete
    ``mode``/``impl``/``grad_impl``); the race times one full level-step
    gradient — BSI expansion + warp + ``similarity`` forward and backward —
    through ``core.ffd.fused_warp_loss`` versus the unfused composition, and
    returns ``base`` with ``fused`` set to the winner.

    Resolves to ``"off"`` without measuring when the fused kernel does not
    apply (custom similarity with no fused spec, volume over the VMEM
    budget) and on backends where Pallas only runs under ``interpret=True``
    (a correctness path, orders of magnitude slower — same exclusion as
    :func:`default_candidates`; set ``REPRO_AUTOTUNE_PALLAS=1`` to force the
    measurement anyway).  Cached like :func:`autotune_bsi`, keyed per
    volume/similarity/dtype/base so fp32 and bf16 (or different unfused
    winners) never share a decision.
    """
    from repro.core import ffd
    from repro.core.similarity import fused_spec
    from repro.kernels import ops as kops

    grid_shape = tuple(int(g) for g in grid_shape)
    tile = tuple(int(t) for t in tile)
    vol_shape = tuple(int(s) for s in vol_shape)
    compute_dtype = (jnp.dtype(compute_dtype).name
                     if compute_dtype is not None else None)

    spec = fused_spec(similarity)
    ok, _ = kops.fused_supported(vol_shape, spec)
    if not ok:
        return dataclasses.replace(base, fused="off")
    if kops.default_interpret() and not os.environ.get("REPRO_AUTOTUNE_PALLAS"):
        return dataclasses.replace(base, fused="off")

    key = (_key(grid_shape, tile, 3)
           + "|fused|v" + "x".join(map(str, vol_shape))
           + f"|sim={similarity_token(similarity)}"
           + ("" if compute_dtype is None else f"|cd={compute_dtype}")
           + f"|base={base.mode}/{base.impl}/{base.grad_impl}")
    cache_path = default_cache_path() if cache_path is None else cache_path
    mem_key = (cache_path, key)
    if use_cache and mem_key in _MEM_CACHE:
        return _MEM_CACHE[mem_key]
    if use_cache:
        hit = _load_disk(cache_path).get(key)
        choice = _parse_choice(hit) if hit else None
        if choice is not None:
            _MEM_CACHE[mem_key] = choice
            return choice

    _, sim_fn = resolve_similarity(similarity)
    dev = jax.local_devices()[0]
    rng = np.random.default_rng(0)
    phi = jax.device_put(
        jnp.asarray(rng.standard_normal(grid_shape + (3,)), jnp.float32), dev)
    mov = jax.device_put(jnp.asarray(rng.random(vol_shape), jnp.float32), dev)
    fix = jax.device_put(jnp.asarray(rng.random(vol_shape), jnp.float32), dev)

    def unfused_loss(p):
        disp = ffd.dense_field(p, tile, vol_shape, mode=base.mode,
                               impl=base.impl, grad_impl=base.grad_impl,
                               compute_dtype=compute_dtype)
        warped = ffd.warp_volume(mov, disp, compute_dtype=compute_dtype)
        return sim_fn(warped.astype(jnp.float32), fix)

    def fused_loss(p):
        return ffd.fused_warp_loss(p, mov, fix, tile, similarity=similarity,
                                   mode=base.mode, impl=base.impl,
                                   grad_impl=base.grad_impl,
                                   compute_dtype=compute_dtype)

    best = dataclasses.replace(base, fused="off")
    timed = []
    for flag, loss in (("off", unfused_loss), ("on", fused_loss)):
        fn = jax.jit(jax.grad(loss))
        try:
            jax.block_until_ready(fn(phi))  # compile + warmup
        except Exception:
            continue  # candidate unavailable on this backend/workload
        times = []
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(phi))
            times.append(time.perf_counter() - t0)
        timed.append((float(np.median(times) * 1e6), flag))
    if timed:
        us, flag = min(timed)
        best = dataclasses.replace(base, fused=flag, us_per_call=us)
    if use_cache:
        _MEM_CACHE[mem_key] = best
        _store_disk(cache_path, key, best)
    return best


def _candidate_pool(mode, impl):
    """Candidates honouring explicitly fixed axes.

    An explicit ``impl`` overrides the backend-based default exclusion (a
    user asking for ``pallas`` on CPU gets interpret-mode Pallas, as the
    seed's explicit ``impl=`` did); only fully-``auto`` axes are subject to
    :func:`default_candidates`.
    """
    if impl == "jnp":
        pool = JNP_CANDIDATES
    elif impl == "pallas":
        pool = PALLAS_CANDIDATES
    else:
        pool = default_candidates()
    return tuple(c for c in pool if mode in ("auto", c[0]))


def resolve_bsi(mode, impl, grid_shape, tile, channels=3, *, grad_impl=None,
                **tune_kwargs):
    """Resolve possibly-``"auto"`` (mode, impl[, grad_impl]) to concrete values.

    Explicit choices pass through untouched; an ``"auto"`` on any axis
    narrows the candidate set to the fixed axes and autotunes the rest.
    With ``grad_impl=None`` (forward-only callers) the return is the
    historical ``(mode, impl)`` pair; passing a ``grad_impl`` — even an
    explicit one — returns ``(mode, impl, grad_impl)`` and, when any axis is
    ``"auto"``, tunes the joint forward+adjoint workload (``measure_grad``
    is implied: the adjoint axis only exists in the backward).
    """
    if grad_impl is None:
        if mode != "auto" and impl != "auto":
            return mode, impl
        cands = _candidate_pool(mode, impl)
        if not cands:
            raise ValueError(
                f"no BSI candidates match mode={mode!r} impl={impl!r}")
        if len(cands) == 1:
            return cands[0]
        choice = autotune_bsi(grid_shape, tile, channels,
                              candidates=cands, **tune_kwargs)
        return choice.mode, choice.impl

    if grad_impl != "auto" and grad_impl not in GRAD_IMPLS:
        raise ValueError(
            f"unknown grad_impl {grad_impl!r}; choose from {GRAD_IMPLS}"
            " or 'auto'")
    if mode != "auto" and impl != "auto" and grad_impl != "auto":
        return mode, impl, grad_impl
    gis = default_grad_impls() if grad_impl == "auto" else (grad_impl,)
    if grad_impl == "auto" and tune_kwargs.get("compute_dtype") is not None:
        # plain autodiff of a reduced-precision forward accumulates the
        # adjoint in that precision; only the analytic adjoints keep the
        # documented fp32 accumulation, so "auto" never picks "xla" here
        # (an *explicit* grad_impl="xla" still passes through above)
        gis = tuple(g for g in gis if g != "xla") or gis
    cands = tuple(c + (gi,) for c in _candidate_pool(mode, impl)
                  for gi in gis)
    if not cands:
        raise ValueError(f"no BSI candidates match mode={mode!r} "
                         f"impl={impl!r} grad_impl={grad_impl!r}")
    if len(cands) == 1:
        return cands[0]
    tune_kwargs["measure_grad"] = True
    choice = autotune_bsi(grid_shape, tile, channels,
                          candidates=cands, **tune_kwargs)
    return choice.mode, choice.impl, choice.grad_impl


@functools.lru_cache(maxsize=256)
def resolve_options(options, vol_shape):
    """Resolve a ``RegistrationOptions`` for a concrete volume shape.

    The options-first face of the tuner: canonicalises the options
    (:meth:`RegistrationOptions.normalized` — similarity key, resolved
    ``stop``) and autotunes any ``"auto"`` BSI axis for the grid this volume
    implies, returning a fully-concrete copy.  ``fused="auto"`` is resolved
    last (:func:`autotune_fused` — the fused level step races the resolved
    unfused winner on the actual volume shape); ``fused="on"`` is validated
    against the fused kernel's applicability and raises with the reason when
    it cannot run.  ``lru_cache``d on ``(options, vol_shape)`` — the
    ``RegistrationOptions`` instance IS the autotune cache key, the same
    object the compiled-runner caches and the serving buckets key on, so one
    validated configuration maps to one tuning decision everywhere.

    Every path records *why* ``fused`` resolved the way it did on the
    returned options' ``fused_reason`` field (introspection only — the
    field is excluded from equality/hash, so it never fragments the
    program caches keyed on the options instance).
    """
    from repro.core import ffd
    from repro.core.options import RegistrationOptions
    from repro.core.similarity import fused_spec
    from repro.kernels import ops as kops

    if not isinstance(options, RegistrationOptions):
        raise TypeError(
            f"resolve_options expects a RegistrationOptions, got {options!r}")
    opts = options.normalized()
    vol_shape = tuple(int(s) for s in vol_shape)
    grid_shape = ffd.grid_shape_for_volume(vol_shape, opts.tile)
    mode, impl, grad_impl = resolve_bsi(
        opts.mode, opts.impl, grid_shape, opts.tile,
        grad_impl=opts.grad_impl,  # the adjoint axis is tuned jointly
        measure_grad=True,  # the loop's workload is forward+backward BSI
        similarity=opts.similarity,  # ... its backward mix is per-similarity
        compute_dtype=opts.compute_dtype,  # ... measured/cached per dtype
        transform=opts.transform,  # ... velocity integrates before the warp
        optimizer=opts.optimizer)  # ... non-default optimisers key apart
    opts = opts.replace(mode=mode, impl=impl, grad_impl=grad_impl)
    is_velocity = isinstance(opts.transform, VelocityTransform)
    from repro.engine.optimizer import GaussNewtonOptimizer

    is_gn = isinstance(opts.optimizer, GaussNewtonOptimizer)
    if opts.fused == "off":
        opts = opts.replace(fused_reason="forced off")
    elif opts.fused == "on":
        if is_velocity:  # unreachable via RegistrationOptions (which raises
            # at construction), but resolve_options is also a public face
            raise ValueError(
                "fused='on' is incompatible with transform='velocity': the "
                "fused level step cannot interleave scaling-and-squaring "
                "compositions; use fused='auto' or 'off'")
        if is_gn:  # same: RegistrationOptions raises at construction
            raise ValueError(
                "fused='on' is incompatible with optimizer='gauss_newton': "
                "the fused level step never materialises the residual "
                "volume Gauss-Newton linearises; use fused='auto' or 'off'")
        ok, why = kops.fused_supported(vol_shape, fused_spec(opts.similarity))
        if not ok:
            raise ValueError(
                f"fused='on' cannot run for this configuration: {why}; "
                "use fused='auto' (or 'off') to fall back to the unfused "
                "level step")
        opts = opts.replace(fused_reason="forced on")
    else:  # fused == "auto"
        if is_velocity:  # no race: the fused step has no velocity path yet
            opts = opts.replace(
                fused="off",
                fused_reason="velocity transform: the fused level step has "
                             "no scaling-and-squaring composition")
        elif is_gn:  # no race: Gauss-Newton linearises the unfused residual
            opts = opts.replace(
                fused="off",
                fused_reason="gauss_newton optimiser: the fused level step "
                             "never materialises the residual volume")
        else:
            ok, why = kops.fused_supported(vol_shape,
                                           fused_spec(opts.similarity))
            if not ok:
                opts = opts.replace(fused="off",
                                    fused_reason=f"unsupported: {why}")
            elif (kops.default_interpret()
                  and not os.environ.get("REPRO_AUTOTUNE_PALLAS")):
                opts = opts.replace(
                    fused="off",
                    fused_reason="interpret-only Pallas backend (set "
                                 "REPRO_AUTOTUNE_PALLAS=1 to race anyway)")
            else:
                choice = autotune_fused(
                    grid_shape, opts.tile, vol_shape,
                    base=BsiChoice(mode, impl, 0.0, grad_impl),
                    similarity=opts.similarity,
                    compute_dtype=opts.compute_dtype)
                opts = opts.replace(
                    fused=choice.fused,
                    fused_reason="autotune: fused level step "
                                 + ("won" if choice.fused == "on"
                                    else "lost") + " the race")
    return opts
