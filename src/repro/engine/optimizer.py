"""Pluggable optimisers: the ``optimizer=`` registry behind every loop.

Adam used to be welded into every layer of the engine — the update in
``engine.convergence``, the scan in ``engine.loop``, the lane chunks in
``engine.batch``/``engine.serve``, the sharded levels in ``engine.shard``
and the runners in ``core.registration``.  The intra-operative latency
targets the ROADMAP points at are reached with *second-order* methods:
Budelmann et al. ("Fully-deformable 3D image registration in two seconds",
PAPERS.md) use L-BFGS, CLAIRE (Brunn et al.) uses Gauss-Newton–Krylov, and
both converge hard pairs in tens of outer iterations where a first-order
loop needs hundreds.  This module makes the optimiser a layer (the shared
``core.registry`` shape, exactly like ``transform=``/``regularizer=``):

``adam``
    The historical loop, bit-identical to the pre-registry engine: the
    shared :func:`adam_update` arithmetic, 1-based f32 bias correction,
    update-then-evaluate step shape.

``lbfgs``
    Limited-memory BFGS: two-loop recursion over a ``history``-pair
    window (statically unrolled, validity-masked — ``lax.scan``/``vmap``
    safe), :math:`\\gamma = s^\\top y / y^\\top y` initial scaling, and a
    backtracking Armijo line search expressed as a *bounded*
    ``lax.while_loop``.  A collapsed line search leaves the iterate (and
    carried gradient/loss) exactly unchanged and reports ``ok=False`` —
    the patience rule counts it as a non-improving step, so a stuck lane
    freezes instead of NaN-ing.  All state is fp32 even under
    ``compute_dtype="bfloat16"`` (params are fp32 throughout the stack;
    the curvature pairs are explicitly cast).

``gauss_newton``
    Gauss-Newton for least-squares similarities (SSD): the objective
    exposes its residual ``r`` with ``sim = mean(r**2)``, the
    :math:`J^\\top J` product is matrix-free (``jax.linearize`` of the
    residual + the linear transpose; the residual is built on the
    XLA-differentiable BSI graph, since forward mode cannot enter the
    analytic custom-VJP adjoint), the regulariser's exact Hessian product
    comes from
    linearising its gradient (both built-in regularizers are quadratic),
    and the normal equations are solved by CG with a fixed iteration cap.
    Levenberg–Marquardt damping is the fallback: a rejected trial step
    (non-finite or non-decreasing loss) leaves the iterate unchanged
    (``ok=False``), raises the damping, and retries next step.

Specs are small frozen dataclasses, so a resolved optimiser drops straight
into ``RegistrationOptions`` as a hashable program-cache-key field; the
factory spellings (``lbfgs(history=10)``) build parameter variants.  The
uniform per-step protocol is :func:`opt_step` (carry invariant: ``g`` and
``loss`` are the gradient/loss *at the current params*), with
:func:`init_state` building the per-optimiser state pytree — nested under
the lane-state dicts of the resumable serving loop, so splicing, masking
and sharding treat it like any other leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.registry import Registry

__all__ = [
    "OPTIMIZERS",
    "AdamOptimizer",
    "LbfgsOptimizer",
    "GaussNewtonOptimizer",
    "Objective",
    "adam",
    "adam_update",
    "available_optimizers",
    "gauss_newton",
    "init_state",
    "lbfgs",
    "make_objective",
    "opt_step",
    "optimizer_token",
    "resolve_optimizer",
]


# --- specs -------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamOptimizer:
    """The historical first-order loop (default; bit-identical to it)."""

    name = "adam"
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    def __post_init__(self):
        for field in ("b1", "b2"):
            v = float(getattr(self, field))
            if not 0.0 <= v < 1.0:
                raise ValueError(f"adam {field} must be in [0, 1), got {v}")
            object.__setattr__(self, field, v)
        eps = float(self.eps)
        if not eps > 0:
            raise ValueError(f"adam eps must be > 0, got {eps}")
        object.__setattr__(self, "eps", eps)


@dataclasses.dataclass(frozen=True)
class LbfgsOptimizer:
    """Limited-memory BFGS with a backtracking Armijo line search.

    ``history`` curvature pairs bound the two-loop recursion (the default
    10 matches scipy's L-BFGS-B ``m``; more pairs cost ``history`` extra
    grid-sized buffers per lane).  The line search backtracks
    ``t = t0, t0*shrink, ...`` for at most ``max_ls`` evaluations against
    the Armijo condition with slope fraction ``c1``, then refines the
    accepted step once by quadratic interpolation; if nothing is accepted
    the step is rejected (``ok=False`` — the iterate does not move and the
    curvature window resets).  ``lr`` is ignored: the natural step of a
    quasi-Newton direction is 1 and the line search owns the scaling.
    """

    name = "lbfgs"
    history: int = 10
    max_ls: int = 10
    c1: float = 1e-4
    shrink: float = 0.5

    def __post_init__(self):
        h = int(self.history)
        if not 1 <= h <= 64:
            raise ValueError(f"lbfgs history must be in [1, 64], got {h}")
        object.__setattr__(self, "history", h)
        m = int(self.max_ls)
        if not 1 <= m <= 64:
            raise ValueError(f"lbfgs max_ls must be in [1, 64], got {m}")
        object.__setattr__(self, "max_ls", m)
        c1 = float(self.c1)
        if not 0.0 < c1 < 1.0:
            raise ValueError(f"lbfgs c1 must be in (0, 1), got {c1}")
        object.__setattr__(self, "c1", c1)
        s = float(self.shrink)
        if not 0.0 < s < 1.0:
            raise ValueError(f"lbfgs shrink must be in (0, 1), got {s}")
        object.__setattr__(self, "shrink", s)


@dataclasses.dataclass(frozen=True)
class GaussNewtonOptimizer:
    """Gauss-Newton with CG inner solves and Levenberg–Marquardt damping.

    Each step solves ``(J^T J * 2/N + H_reg + damping I) d = -g`` with at
    most ``cg_iters`` CG iterations (matrix-free ``J^T J`` products) and
    trials ``p + d``: an accepted step (finite, lower loss) divides the
    damping by ``damp_down``, a rejected one multiplies it by ``damp_up``
    and leaves the iterate unchanged (``ok=False``) — the LM fallback that
    degrades toward (damped) gradient descent instead of diverging.  Only
    valid for residual objectives (``similarity="ssd"``); ``lr`` is
    ignored — the Newton step has its own natural length.
    """

    name = "gauss_newton"
    cg_iters: int = 10
    damping: float = 1e-3
    damp_up: float = 10.0
    damp_down: float = 3.0
    min_damping: float = 1e-8
    max_damping: float = 1e8

    def __post_init__(self):
        k = int(self.cg_iters)
        if not 1 <= k <= 256:
            raise ValueError(
                f"gauss_newton cg_iters must be in [1, 256], got {k}")
        object.__setattr__(self, "cg_iters", k)
        for field in ("damping", "damp_up", "damp_down",
                      "min_damping", "max_damping"):
            v = float(getattr(self, field))
            if not v > 0:
                raise ValueError(
                    f"gauss_newton {field} must be > 0, got {v}")
            object.__setattr__(self, field, v)
        if self.damp_up <= 1.0 or self.damp_down <= 1.0:
            raise ValueError(
                "gauss_newton damp_up/damp_down must be > 1 (they "
                "multiply/divide the damping on reject/accept), got "
                f"{self.damp_up}/{self.damp_down}")


_SPEC_TYPES = (AdamOptimizer, LbfgsOptimizer, GaussNewtonOptimizer)

OPTIMIZERS = Registry(
    "optimizer", passthrough=lambda o: isinstance(o, _SPEC_TYPES))


def adam(b1=0.9, b2=0.999, eps=1e-8) -> AdamOptimizer:
    """The historical Adam loop spec (the default)."""
    return AdamOptimizer(b1=b1, b2=b2, eps=eps)


def lbfgs(history=10, max_ls=10, c1=1e-4, shrink=0.5) -> LbfgsOptimizer:
    """An L-BFGS spec with the given history window / line-search knobs."""
    return LbfgsOptimizer(history=history, max_ls=max_ls, c1=c1,
                          shrink=shrink)


def gauss_newton(cg_iters=10, damping=1e-3, damp_up=10.0,
                 damp_down=3.0) -> GaussNewtonOptimizer:
    """A Gauss-Newton/LM spec with the given CG cap and damping schedule."""
    return GaussNewtonOptimizer(cg_iters=cg_iters, damping=damping,
                                damp_up=damp_up, damp_down=damp_down)


OPTIMIZERS.register("adam", AdamOptimizer())
OPTIMIZERS.register("lbfgs", LbfgsOptimizer())
OPTIMIZERS.register("gauss_newton", GaussNewtonOptimizer())


def available_optimizers():
    """Sorted names of the registered optimisers."""
    return OPTIMIZERS.names()


def resolve_optimizer(optimizer):
    """Resolve a name-or-spec to a frozen optimiser spec instance."""
    _, spec = OPTIMIZERS.resolve(optimizer)
    return spec


def optimizer_token(optimizer) -> str:
    """A short string naming the optimiser for cache keys and logs.

    The default Adam tokenises to plain ``"adam"`` so pre-registry cache
    entries (runner ``lru_cache`` keys aside, the autotune *disk* cache)
    stay valid — only non-default optimisers grow a token.
    """
    spec = resolve_optimizer(optimizer)
    if isinstance(spec, AdamOptimizer):
        if spec == AdamOptimizer():
            return "adam"
        return f"adam(b1={spec.b1:g},b2={spec.b2:g},eps={spec.eps:g})"
    if isinstance(spec, LbfgsOptimizer):
        return f"lbfgs(history={spec.history},max_ls={spec.max_ls})"
    return (f"gauss_newton(cg={spec.cg_iters},"
            f"damping={spec.damping:g})")


# --- the objective -----------------------------------------------------------


class Objective(NamedTuple):
    """What a step needs to know about the function it minimises.

    ``loss``/``vg`` are always present (``vg`` is
    ``jax.value_and_grad(loss)`` — the one gradient evaluation per step
    every optimiser shares).  ``residual``/``reg`` exist only for
    least-squares objectives: ``residual(p)`` is the flat residual vector
    with ``similarity = mean(residual**2)`` and ``reg(p)`` the (quadratic)
    regularisation term, so ``loss(p) == mean(residual(p)**2) + reg(p)`` —
    what Gauss-Newton linearises.  First-order optimisers ignore them.
    """

    loss: Callable
    vg: Callable
    residual: Any = None
    reg: Any = None


def make_objective(loss_fn, *, residual_fn=None, reg_fn=None) -> Objective:
    """Wrap a scalar loss (and optional residual form) as an :class:`Objective`.

    When only ``residual_fn``/``reg_fn`` are given (``loss_fn=None``), the
    loss is assembled as ``mean(residual**2) + reg`` — but callers that
    already have the composite loss should pass it, so the scalar path is
    bit-identical to the objective the first-order loop always ran.
    """
    if loss_fn is None:
        if residual_fn is None:
            raise ValueError("make_objective needs loss_fn or residual_fn")

        def loss_fn(p):
            r = residual_fn(p)
            sim = jnp.mean(jnp.square(r))
            return sim + (reg_fn(p) if reg_fn is not None else 0.0)

    return Objective(loss=loss_fn, vg=jax.value_and_grad(loss_fn),
                     residual=residual_fn, reg=reg_fn)


# --- the shared update arithmetic -------------------------------------------


def adam_update(p, m, v, g, i, *, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam update (bias-corrected with step index ``i``, 1-based).

    The single source of the update arithmetic — shared (via the ``adam``
    registry entry) by the fixed-length scan (``engine.loop``), the
    early-stopped while loop (``engine.convergence``) and the resumable
    serving chunks (``engine.batch``), so every trajectory is step-for-step
    identical.  Re-exported from ``engine.convergence`` for compatibility.
    """
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**i)
    vh = v / (1 - b2**i)
    return p - lr * mh / (jnp.sqrt(vh) + eps), m, v


def _dot(a, b):
    """Flat fp32 dot product of two same-shaped arrays."""
    return jnp.vdot(a.astype(jnp.float32), b.astype(jnp.float32))


# --- per-optimiser state + step ---------------------------------------------


def init_state(optimizer, params) -> dict:
    """The optimiser-state pytree for ``params`` (a dict of fp32 leaves).

    Every leaf's leading structure is static, so the state nests inside
    lane-state dicts (``engine.batch``/``engine.serve``) and stacks,
    splices, masks and shards like any other leaf.  State is fp32 by
    construction even when the objective computes in reduced precision.
    """
    spec = resolve_optimizer(optimizer)
    p32 = jnp.zeros(jnp.shape(params), jnp.float32)
    if isinstance(spec, AdamOptimizer):
        return {"m": p32, "v": p32}
    if isinstance(spec, LbfgsOptimizer):
        h = spec.history
        return {
            "s": jnp.zeros((h,) + p32.shape, jnp.float32),
            "y": jnp.zeros((h,) + p32.shape, jnp.float32),
            "rho": jnp.zeros((h,), jnp.float32),
            "hlen": jnp.zeros((), jnp.int32),
        }
    return {"damping": jnp.float32(spec.damping)}


def opt_step(optimizer, obj, k, p, opt, g, loss, *, lr):
    """One optimisation step under the uniform carry protocol.

    Carry invariant: ``g``/``loss`` are the gradient and loss of ``obj``
    at the *current* ``p`` (seeded by one ``obj.vg(p0)`` evaluation before
    the loop).  Returns ``(p1, opt1, g1, loss1, ok)`` restoring the same
    invariant at ``p1``; ``ok`` is False when the step was rejected (a
    collapsed L-BFGS line search, a refused Gauss-Newton trial), in which
    case ``p1``/``g1``/``loss1`` equal their inputs numerically — the
    iterate did not move — and the plateau rule must count the step as
    non-improving.  Pure per-lane arithmetic throughout (bounded loops,
    validity masks, no data-dependent shapes), so the step composes with
    ``lax.scan``/``lax.while_loop``/``vmap``/mesh sharding unchanged.
    """
    spec = resolve_optimizer(optimizer)
    if isinstance(spec, AdamOptimizer):
        return _adam_step(spec, obj, k, p, opt, g, lr=lr)
    if isinstance(spec, LbfgsOptimizer):
        return _lbfgs_step(spec, obj, p, opt, g, loss)
    return _gauss_newton_step(spec, obj, p, opt, g, loss)


def _adam_step(spec, obj, k, p, opt, g, *, lr):
    # exactly the pre-registry plateau_step arithmetic: 1-based f32 bias
    # correction, update first, then one value_and_grad at the new params
    i = (k + 1).astype(jnp.float32)
    p, m, v = adam_update(p, opt["m"], opt["v"], g, i, lr=lr,
                          b1=spec.b1, b2=spec.b2, eps=spec.eps)
    loss, g = obj.vg(p)
    return p, {"m": m, "v": v}, g, loss, jnp.bool_(True)


def _lbfgs_step(spec, obj, p, opt, g, loss):
    h = spec.history
    f32 = jnp.float32
    g32 = g.astype(f32)
    s_hist, y_hist, rho, hlen = opt["s"], opt["y"], opt["rho"], opt["hlen"]

    # two-loop recursion, newest pair at index 0; the window is statically
    # unrolled with per-slot validity masks so the recursion is vmap-safe
    q = g32
    alphas = []
    for i in range(h):
        valid = i < hlen
        a = jnp.where(valid, rho[i] * _dot(s_hist[i], q), f32(0.0))
        q = q - a * y_hist[i]
        alphas.append(a)
    gamma = jnp.where(
        hlen > 0,
        _dot(s_hist[0], y_hist[0])
        / jnp.maximum(_dot(y_hist[0], y_hist[0]), f32(1e-30)),
        f32(1.0))
    r = gamma * q
    for i in range(h - 1, -1, -1):
        valid = i < hlen
        b = jnp.where(valid, rho[i] * _dot(y_hist[i], r), f32(0.0))
        r = r + (alphas[i] - b) * s_hist[i]
    d = -r

    # descent safeguard: a degenerate window can produce an ascent (or
    # non-finite) direction — restart from steepest descent
    dg = _dot(d, g32)
    bad = jnp.logical_or(dg >= 0, jnp.logical_not(jnp.isfinite(dg)))
    d = jnp.where(bad, -g32, d)
    dg = jnp.where(bad, -_dot(g32, g32), dg)

    # backtracking Armijo line search as a bounded while_loop; a step with
    # no curvature history (the start, or a post-collapse restart) probes a
    # unit-norm displacement first — a raw registration gradient can be
    # orders of magnitude smaller than the control-point displacements the
    # problem needs, and the backtracker shrinks from there
    gnorm = jnp.sqrt(_dot(d, d))
    t0 = jnp.where(hlen > 0, f32(1.0),
                   f32(1.0) / jnp.maximum(gnorm, f32(1e-12)))
    c1, shrink = f32(spec.c1), f32(spec.shrink)

    def ls_cond(c):
        j, _, _, _, found = c
        return jnp.logical_and(j < spec.max_ls, jnp.logical_not(found))

    def ls_body(c):
        j, t, t_acc, f_acc, found = c
        f_t = obj.loss(p + t * d).astype(f32)
        accept = jnp.logical_and(jnp.isfinite(f_t),
                                 f_t <= loss.astype(f32) + c1 * t * dg)
        t_acc = jnp.where(accept, t, t_acc)
        f_acc = jnp.where(accept, f_t, f_acc)
        return j + 1, t * shrink, t_acc, f_acc, jnp.logical_or(found, accept)

    _, _, t_acc, f_acc, ok = jax.lax.while_loop(
        ls_cond, ls_body,
        (jnp.zeros((), jnp.int32), t0, f32(0.0), loss.astype(f32),
         jnp.bool_(False)))

    # one quadratic-interpolation refinement of the accepted step: fit the
    # 1-D quadratic through (f(p), dg, f(p + t_acc d)) and probe its
    # minimiser (clipped to [0, 8 t_acc]) — a single extra forward eval
    # that lands near the line optimum when backtracking over/undershoots.
    # Kept only when it strictly improves, so a collapsed search stays
    # collapsed (t_acc = 0 fits a zero-length quadratic and is unchanged).
    denom = 2.0 * (f_acc - loss.astype(f32) - dg * t_acc)
    t_q = jnp.where(denom > 0,
                    -dg * t_acc * t_acc / jnp.maximum(denom, f32(1e-30)),
                    t_acc)
    t_q = jnp.clip(t_q, f32(0.0), 8.0 * t_acc)
    f_q = obj.loss(p + t_q * d).astype(f32)
    refine = jnp.logical_and(ok,
                             jnp.logical_and(jnp.isfinite(f_q), f_q < f_acc))
    t_acc = jnp.where(refine, t_q, t_acc)

    # t_acc is 0 on a collapsed search, so p1 == p exactly and the fresh
    # vg(p1) reproduces the carried (loss, g) — the whole step stays
    # select-free, which is what keeps the rejected-lane carry identical
    # to a frozen lane under vmap masking
    p1 = p + t_acc * d
    loss1, g1 = obj.vg(p1)

    # curvature-gated history push (only accepted steps with s^T y > 0
    # keep the inverse-Hessian approximation positive definite)
    s_new = (p1 - p).astype(f32)
    y_new = (g1 - g).astype(f32)
    sy = _dot(s_new, y_new)
    push = jnp.logical_and(ok, sy > f32(1e-10))
    s_roll = jnp.concatenate([s_new[None], s_hist[:-1]], axis=0)
    y_roll = jnp.concatenate([y_new[None], y_hist[:-1]], axis=0)
    rho_roll = jnp.concatenate(
        [(f32(1.0) / jnp.maximum(sy, f32(1e-30)))[None], rho[:-1]])
    # a collapsed search drops the curvature window: the quasi-Newton
    # direction it produced is not usable at any tried scale, and keeping
    # the window would re-propose the identical step (the state is the
    # whole carry) — a deterministic deadlock.  Resetting restarts the
    # next step from safeguarded steepest descent.
    hlen1 = jnp.where(ok, jnp.where(push, jnp.minimum(hlen + 1, h), hlen),
                      jnp.zeros((), jnp.int32))
    opt1 = {
        "s": jnp.where(push, s_roll, s_hist),
        "y": jnp.where(push, y_roll, y_hist),
        "rho": jnp.where(push, rho_roll, rho),
        "hlen": hlen1,
    }
    return p1, opt1, g1, loss1, ok


def _gauss_newton_step(spec, obj, p, opt, g, loss):
    if obj.residual is None:
        raise ValueError(
            "optimizer='gauss_newton' needs a residual objective "
            "(similarity='ssd'); this objective has none")
    f32 = jnp.float32
    lam = opt["damping"]

    # linearise the residual once per step: J^T J v = vjp(jvp(v)).  The
    # residual must live on a jvp-capable graph (no custom_vjp inside) —
    # ffd_level_objective pins its residual to grad_impl="xla" for this.
    r0, jvp_fn = jax.linearize(obj.residual, p)
    vjp_fn = jax.linear_transpose(jvp_fn, p)
    n = f32(r0.size)

    if obj.reg is not None:
        # both built-in regularizers are quadratic in p, so linearising
        # their gradient gives the exact Hessian product
        _, reg_hvp = jax.linearize(jax.grad(obj.reg), p)
    else:
        def reg_hvp(v):
            return jnp.zeros_like(v)

    def hess_v(v):
        (jtjv,) = vjp_fn(jvp_fn(v))
        return (2.0 / n) * jtjv + reg_hvp(v) + lam * v

    # CG on (H + lam I) d = -g, fixed cap (matrix-free, vmap-safe)
    b = -g.astype(f32)

    def cg_body(_, c):
        x, res, direc, rs = c
        hd = hess_v(direc)
        denom = _dot(direc, hd)
        alpha = rs / jnp.maximum(denom, f32(1e-30))
        x = x + alpha * direc
        res = res - alpha * hd
        rs_new = _dot(res, res)
        beta = rs_new / jnp.maximum(rs, f32(1e-30))
        return x, res, res + beta * direc, rs_new

    d, _, _, _ = jax.lax.fori_loop(
        0, spec.cg_iters, cg_body,
        (jnp.zeros_like(b), b, b, _dot(b, b)))

    # LM trial: accept only a finite, strictly lower loss; a rejected step
    # leaves the iterate exactly in place (gain = 0-scaled direction) and
    # raises the damping for the next attempt
    loss_try = obj.loss(p + d).astype(f32)
    ok = jnp.logical_and(jnp.isfinite(loss_try),
                         loss_try < loss.astype(f32))
    ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(d)))
    p1 = p + jnp.where(ok, f32(1.0), f32(0.0)) * d
    loss1, g1 = obj.vg(p1)
    lam1 = jnp.where(
        ok,
        jnp.maximum(lam / f32(spec.damp_down), f32(spec.min_damping)),
        jnp.minimum(lam * f32(spec.damp_up), f32(spec.max_damping)))
    return p1, {"damping": lam1}, g1, loss1, ok
