"""Batched, fully-jitted FFD registration — the "serve heavy traffic" primitive.

``ffd_pipeline`` is the whole multi-level FFD optimisation (pyramid,
scan-based Adam per level, grid upsampling between levels, final warp) as a
pure traced function of ``(fixed, moving)``.  That purity is the point: it
``vmap``s over a leading batch axis, so ``register_batch`` registers N volume
pairs in ONE jitted program — no Python-loop dispatch anywhere, and XLA is
free to batch every BSI expansion, gradient, and Adam update across pairs.

Compiled programs are cached per configuration (shapes x
``RegistrationOptions``), so a serving loop pays one compile per volume
geometry and then runs back-to-back batches at device speed.  For the
continuous-batching scheduler (``engine.serve``) this module also provides
the *resumable* form: ``compile_level_chunk`` runs a fixed-width lane array
through ``chunk`` masked Adam steps of one pyramid level and hands the whole
optimiser state back to the host, so converged lanes can be spliced out and
queued pairs spliced in between chunks.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ffd
from repro.core.options import UNSET, merge_legacy_options
from repro.core.regularizer import regularizer_term
from repro.core.similarity import resolve_similarity
from repro.core.transform import (VelocityTransform, dense_displacement,
                                  resolve_transform)
from repro.engine.convergence import (level_live, optimize_plateau_step,
                                      optimize_until)
from repro.engine.loop import optimize_scan
from repro.engine.optimizer import init_state, make_objective

__all__ = ["BatchRegistrationResult", "ffd_level_loss", "ffd_level_objective",
           "ffd_pipeline", "register_batch", "level_vol_shapes",
           "compile_level_chunk", "compile_level_init",
           "compile_level_splice", "compile_finish"]


@dataclasses.dataclass
class BatchRegistrationResult:
    warped: Any     # (B, X, Y, Z) registered moving volumes
    params: Any     # (B, *grid_shape, 3) finest-level control grids
    losses: Any     # (B, levels) final loss per pyramid level
    seconds: float  # wall time for the whole batch (see ``compiled``)
    # True when this call (re)compiled the batch program: ``seconds`` then
    # includes the one-time trace+compile and is NOT a steady-state batch
    # time — time a second call (or check this flag) before comparing.
    compiled: bool = False
    # (B, levels) int32 Adam steps actually run per pair per level when the
    # call used early stopping (``stop=``); None under fixed-``iters``.
    steps: Any = None


def ffd_level_loss(f, mov, *, tile, bending_weight, mode, impl,
                   grad_impl="xla", compute_dtype=None, similarity="ssd",
                   transform="displacement", regularizer="none",
                   fused="off"):
    """Similarity + regularisation objective for one pyramid level.

    ``similarity`` is a registered name or a ``(warped, fixed) -> scalar``
    loss callable (lower = better; see ``repro.core.similarity``).  Shared
    verbatim by the per-pair path (``core.registration.ffd_register``) and
    the batched path so the two produce matching optimisations.
    ``grad_impl`` picks the BSI adjoint (``xla`` autodiff vs the analytic
    gather-only custom VJP — see ``repro.core.interpolate``);
    ``compute_dtype`` runs the BSI expansion + warp in reduced precision
    (params, adjoint accumulation and the objective stay fp32).

    ``transform`` (name or spec, see ``repro.core.transform``) picks how
    the control grid becomes a displacement: classic FFD (default) or a
    stationary velocity field integrated by scaling and squaring.
    ``regularizer`` (see ``repro.core.regularizer``) picks the smoothness
    term: ``"none"`` keeps the historical ``bending_weight``
    finite-difference proxy; ``"bending"`` replaces it with the analytic
    B-spline bending energy at the spec's own weight.

    ``fused="on"`` (or ``True``) swaps the similarity term for the fused
    Pallas level step (``core.ffd.fused_warp_loss``): BSI displacement +
    warp + similarity partial sums in one VMEM pass, no ``(X, Y, Z, 3)``
    field or warped volume in HBM, with the gradient recomputed through the
    unfused composition (so it is identical).  Requires a similarity with a
    fused accumulator and the ``displacement`` transform (the megakernel
    cannot interleave velocity compositions); the regularisation term stays
    outside (it reads only the control grid).
    """
    vol_shape = f.shape
    _, sim = resolve_similarity(similarity)
    tspec = resolve_transform(transform)
    gshape = ffd.grid_shape_for_volume(vol_shape, tile)
    reg = regularizer_term(regularizer, grid_shape=gshape, tile=tile,
                           bending_weight=bending_weight)

    if fused in ("on", True):
        if isinstance(tspec, VelocityTransform):
            raise ValueError(
                "fused='on' cannot run the velocity transform: the fused "
                "level step has no scaling-and-squaring composition; use "
                "fused='off' (or 'auto') with transform='velocity'")

        def loss_fn(p):
            simloss = ffd.fused_warp_loss(
                p, mov, f, tile, similarity=similarity, mode=mode, impl=impl,
                grad_impl=grad_impl, compute_dtype=compute_dtype)
            return simloss + reg(p)

        return loss_fn

    def loss_fn(p):
        disp = dense_displacement(tspec, p, tile, vol_shape, mode=mode,
                                  impl=impl, grad_impl=grad_impl,
                                  compute_dtype=compute_dtype)
        warped = ffd.warp_volume(mov, disp, compute_dtype=compute_dtype)
        # score the objective in fp32 regardless of input dtype: casting to
        # f.dtype would silently score a bf16 fixed volume (similarity AND
        # its trade-off against the fp32 regulariser) in bf16
        warped = warped.astype(jnp.float32)
        fixed32 = f.astype(jnp.float32)
        return sim(warped, fixed32) + reg(p)

    return loss_fn


def ffd_level_objective(f, mov, *, tile, bending_weight, mode, impl,
                        grad_impl="xla", compute_dtype=None, similarity="ssd",
                        transform="displacement", regularizer="none",
                        fused="off"):
    """The :func:`ffd_level_loss` objective as an ``engine.optimizer.Objective``.

    The scalar loss (and its ``value_and_grad``) is :func:`ffd_level_loss`
    verbatim — the first-order path through this wrapper is bit-identical
    to calling the loss directly.  When the similarity is the canonical
    ``"ssd"`` (``mean((warped - fixed)**2)``) and the level is unfused, the
    objective additionally exposes the least-squares *residual* form
    ``r(p) = (warped - fixed).ravel()`` plus the standalone regulariser
    term — what ``optimizer="gauss_newton"`` linearises for its matrix-free
    ``J^T J`` products (on the XLA-differentiable BSI graph: forward-mode
    ``jax.linearize`` cannot enter the analytic custom-VJP adjoint, which
    stays on the gradient path only).  Any other similarity (including callables and the fused
    megakernel, whose partial-sum accumulator never materialises the
    residual volume) yields a scalar-only objective, which the Gauss-Newton
    step rejects with a clear error.
    """
    loss_fn = ffd_level_loss(
        f, mov, tile=tile, bending_weight=bending_weight, mode=mode,
        impl=impl, grad_impl=grad_impl, compute_dtype=compute_dtype,
        similarity=similarity, transform=transform, regularizer=regularizer,
        fused=fused)
    key, _ = resolve_similarity(similarity)
    if key != "ssd" or fused in ("on", True):
        return make_objective(loss_fn)

    vol_shape = f.shape
    tspec = resolve_transform(transform)
    gshape = ffd.grid_shape_for_volume(vol_shape, tile)
    reg = regularizer_term(regularizer, grid_shape=gshape, tile=tile,
                           bending_weight=bending_weight)
    fixed32 = f.astype(jnp.float32)

    def residual_fn(p):
        # grad_impl is pinned to "xla" here: Gauss-Newton linearises the
        # residual with jax.linearize (forward mode), and the analytic
        # adjoint is a custom_vjp with no JVP rule.  The forward values are
        # identical either way — grad_impl only swaps the backward graph —
        # so the gradient path (obj.vg, above) keeps the configured adjoint.
        disp = dense_displacement(tspec, p, tile, vol_shape, mode=mode,
                                  impl=impl, grad_impl="xla",
                                  compute_dtype=compute_dtype)
        warped = ffd.warp_volume(mov, disp, compute_dtype=compute_dtype)
        return (warped.astype(jnp.float32) - fixed32).ravel()

    return make_objective(loss_fn, residual_fn=residual_fn, reg_fn=reg)


def ffd_pipeline(fixed, moving, *, tile, levels, iters, lr, bending_weight,
                 mode, impl, grad_impl="xla", compute_dtype=None,
                 similarity="ssd", transform="displacement",
                 regularizer="none", stop=None, fused="off",
                 optimizer="adam"):
    """Pure multi-level FFD registration of ONE ``(fixed, moving)`` pair.

    Traceable end-to-end (no timing, no host sync): the levels unroll into
    the trace and each level's inner loop is a ``lax.scan``
    (``engine.loop.optimize_scan``) — or, with a resolved
    ``ConvergenceConfig`` as ``stop``, the early-stopped ``lax.while_loop``
    (``engine.convergence.optimize_until``), under which ``vmap``ped lanes
    freeze as they converge and the level exits when the last lane is done.
    ``optimizer`` is a registered name or spec (``engine.optimizer``;
    default ``"adam"``, bit-identical to the pre-registry pipeline) — the
    optimiser state restarts fresh at each level (the grid changes shape
    between levels, so curvature history cannot carry across).  Returns
    ``(warped, phi, level_losses)``; with ``stop`` set, ``(warped, phi,
    level_losses, level_steps)`` where ``level_steps[l]`` is the optimiser
    steps level ``l`` actually ran.
    """
    pyramid = [(fixed, moving)]
    for _ in range(levels - 1):
        f, m = pyramid[-1]
        pyramid.append((ffd.downsample2(f), ffd.downsample2(m)))
    pyramid = pyramid[::-1]  # coarse -> fine

    phi = None
    finals = []
    steps = []
    for f, m in pyramid:
        gshape = ffd.grid_shape_for_volume(f.shape, tile)
        phi = (jnp.zeros(gshape + (3,), jnp.float32) if phi is None
               else ffd.upsample_grid(phi, gshape))
        obj = ffd_level_objective(f, m, tile=tile,
                                  bending_weight=bending_weight,
                                  mode=mode, impl=impl, grad_impl=grad_impl,
                                  compute_dtype=compute_dtype,
                                  similarity=similarity, transform=transform,
                                  regularizer=regularizer, fused=fused)
        if stop is None:
            phi, trace = optimize_scan(obj, phi, optimizer=optimizer,
                                       iters=iters, lr=lr)
        else:
            phi, trace, taken = optimize_until(obj, phi, optimizer=optimizer,
                                               stop=stop, lr=lr)
            steps.append(taken)
        finals.append(trace[-1])

    disp = dense_displacement(transform, phi, tile, fixed.shape, mode=mode,
                              impl=impl, grad_impl=grad_impl)
    warped = ffd.warp_volume(moving, disp)
    if stop is None:
        return warped, phi, jnp.stack(finals)
    return warped, phi, jnp.stack(finals), jnp.stack(steps)


@functools.lru_cache(maxsize=32)
def _compiled_batch(vol_shape, options, mesh=None):
    """One compiled program per (shape, options, mesh).

    ``options`` is a *resolved* ``RegistrationOptions`` (concrete
    mode/impl/grad_impl, canonical similarity key, resolved ``stop``) — the
    sole configuration cache key.  ``mesh`` is part of the key too
    (``jax.sharding.Mesh`` hashes by devices + axis names), so single-device
    and pod-sharded callers never collide, and two meshes over the same
    devices share a compile.  The early-stopped while-loop program and the
    fixed-length scan program differ through ``options.stop``."""
    del vol_shape  # cache key only; jax re-traces on new shapes anyway
    o = options
    if mesh is not None:
        from repro.engine.shard import compile_sharded_batch

        return compile_sharded_batch(mesh, o.tile, o.levels, o.iters, o.lr,
                                     o.bending_weight, o.mode, o.impl,
                                     o.similarity, grad_impl=o.grad_impl,
                                     compute_dtype=o.compute_dtype,
                                     transform=o.transform,
                                     regularizer=o.regularizer,
                                     stop=o.stop, fused=o.fused,
                                     optimizer=o.optimizer)

    def single(f, m):
        return ffd_pipeline(f, m, tile=o.tile, levels=o.levels,
                            iters=o.iters, lr=o.lr,
                            bending_weight=o.bending_weight,
                            mode=o.mode, impl=o.impl, grad_impl=o.grad_impl,
                            compute_dtype=o.compute_dtype,
                            similarity=o.similarity, transform=o.transform,
                            regularizer=o.regularizer, stop=o.stop,
                            fused=o.fused, optimizer=o.optimizer)

    return jax.jit(jax.vmap(single))


def register_batch(fixed, moving, *, options=None, tile=UNSET, levels=UNSET,
                   iters=UNSET, lr=UNSET, bending_weight=UNSET, mode=UNSET,
                   impl=UNSET, grad_impl=UNSET, compute_dtype=UNSET,
                   similarity=UNSET, transform=UNSET, regularizer=UNSET,
                   mesh=None, stop=UNSET, optimizer=UNSET):
    """Register a batch of volume pairs in a single jitted program.

    Args:
      fixed, moving: ``(B, X, Y, Z)`` stacks of volume pairs (B >= 1).
      options: a ``repro.core.RegistrationOptions`` — the preferred way to
        configure the run; the remaining keyword arguments are the legacy
        per-field spelling (as ``core.registration.ffd_register``), kept
        working through a deprecation shim and bit-identical to the
        equivalent ``options=``.  ``mode``/``impl``/``grad_impl`` default to
        ``"auto"`` — the ``engine.autotune`` winner for this ``(grid_shape,
        tile)`` under the chosen ``similarity``'s joint forward+backward
        workload (the adjoint axis picks between XLA autodiff and the
        analytic gather-only custom VJP).  ``compute_dtype`` (e.g.
        ``"bfloat16"``) runs BSI + warp in reduced precision with fp32
        params/adjoint accumulation.  ``similarity`` is a registered name
        (``"ssd" | "ncc" | "lncc" | "nmi"``) or a loss callable.
        ``transform`` (``"displacement" | "velocity"`` or a
        ``repro.core.transform`` spec) picks the deformation model —
        ``"velocity"`` yields diffeomorphic, fold-free warps; ``regularizer``
        (``"none" | "bending"`` or a ``repro.core.regularizer`` spec) picks
        the smoothness term.  ``optimizer`` (``"adam" | "lbfgs" |
        "gauss_newton"`` or an ``engine.optimizer`` spec) picks the per-level
        optimisation loop — the default ``"adam"`` is bit-identical to the
        pre-registry engine; ``"gauss_newton"`` requires
        ``similarity="ssd"``.
      mesh: optional ``jax.sharding.Mesh`` (see
        ``engine.shard.make_registration_mesh``) — the batch axis shards
        over the mesh's data axes (``REGISTRATION_RULES``), one program
        serving all devices.  Non-divisible batches are padded (repeating
        the last pair) and stripped on return, so results are identical to
        the unsharded path for any B.  Deliberately *not* an options field:
        it names physical devices, so it would poison option-keyed caches.
      stop: optional ``ConvergenceConfig`` — run each pyramid level as an
        early-stopped ``lax.while_loop`` instead of a fixed-``iters`` scan
        (``stop.max_iters`` defaults to ``iters``).  Converged pairs (and
        ``pad_batch`` filler lanes) freeze — their updates are masked and
        their best-visited params are returned — and the level exits as
        soon as the *last* lane converges, so a batch of easy pairs
        finishes in a fraction of the budget.  Note the SPMD cost model:
        until that exit, frozen lanes still execute the (masked) BSI work,
        so a mixed batch's wall-clock is set by its slowest pair — the
        ``steps`` array the result gains counts optimiser steps per pair
        (quality/accounting), not wall-clock saved.  ``stop=None``
        (default) is the fixed-iteration pipeline, bit-identical to not
        passing ``stop``.

    Returns a :class:`BatchRegistrationResult`; ``warped[b]`` matches what
    per-pair ``ffd_register`` produces for pair ``b``.
    """
    fixed = jnp.asarray(fixed, jnp.float32)
    moving = jnp.asarray(moving, jnp.float32)
    if fixed.ndim != 4:
        raise ValueError(
            f"register_batch expects (B, X, Y, Z) stacks, got {fixed.shape}; "
            "use ffd_register for a single pair")
    if fixed.shape[0] == 0:
        raise ValueError(
            "register_batch got an empty batch (B=0); supply at least one "
            "(fixed, moving) pair")
    if fixed.shape != moving.shape:
        raise ValueError(f"shape mismatch: {fixed.shape} vs {moving.shape}")
    opts = merge_legacy_options(
        "register_batch", options,
        dict(tile=tile, levels=levels, iters=iters, lr=lr,
             bending_weight=bending_weight, mode=mode, impl=impl,
             grad_impl=grad_impl, compute_dtype=compute_dtype,
             similarity=similarity, transform=transform,
             regularizer=regularizer, stop=stop, optimizer=optimizer))

    from repro.engine.autotune import resolve_options

    # NOTE: the autotune workload pins stop=None — the winner is measured on
    # the fixed-iteration forward+backward BSI step, which is exactly the
    # per-step work an early-stopped loop runs (stopping changes how many
    # steps execute, never which kernel each step should use).
    opts = resolve_options(opts, fixed.shape[1:])

    t0 = time.perf_counter()
    b = fixed.shape[0]
    if mesh is not None:
        from repro.engine.shard import batch_multiple, pad_batch

        fixed, b = pad_batch(fixed, batch_multiple(mesh))
        moving, _ = pad_batch(moving, batch_multiple(mesh))
    misses = _compiled_batch.cache_info().misses
    fn = _compiled_batch(fixed.shape[1:], opts, mesh)
    compiled = _compiled_batch.cache_info().misses > misses
    stop = opts.stop
    out = fn(fixed, moving)
    warped, phi, losses = out[:3]
    steps = out[3] if stop is not None else None
    jax.block_until_ready(warped)
    seconds = time.perf_counter() - t0
    if mesh is not None:  # strip the pad rows (see engine.shard.pad_batch)
        warped, phi, losses = warped[:b], phi[:b], losses[:b]
        steps = steps[:b] if steps is not None else None
    return BatchRegistrationResult(warped, phi, losses, seconds,
                                   compiled=compiled, steps=steps)


# ---------------------------------------------------------------------------
# Resumable chunked execution — the continuous-batching substrate.
#
# ``register_batch`` runs each pyramid level to completion inside one
# program, so a new pair can only join at batch boundaries.  The serving
# scheduler (``engine.serve``) instead drives each level in fixed-size
# *chunks* of masked optimiser steps over a fixed-width lane array: after
# every chunk the full optimiser state returns to the host, converged lanes
# are harvested and queued pairs spliced into the freed slots.  The per-step
# arithmetic is ``engine.convergence.optimize_plateau_step`` — the exact body
# of ``optimize_until`` — so a lane's trajectory is step-for-step identical
# to the uninterrupted while-loop no matter how chunks and lane recycling
# slice it.  The optimiser state nests under the lane dict's ``"opt"`` key
# (``engine.optimizer.init_state``), so splicing and masking are plain
# ``jax.tree.map`` over the lane pytree for every registered optimiser.
# ---------------------------------------------------------------------------


def level_vol_shapes(vol_shape, levels):
    """Per-level volume shapes, coarse -> fine (``downsample2`` geometry)."""
    shapes = [tuple(int(s) for s in vol_shape)]
    for _ in range(int(levels) - 1):
        shapes.append(tuple((s - s % 2) // 2 for s in shapes[-1]))
    return shapes[::-1]


def _lane_obj(f, m, options):
    o = options
    return ffd_level_objective(
        f, m, tile=o.tile, bending_weight=o.bending_weight, mode=o.mode,
        impl=o.impl, grad_impl=o.grad_impl, compute_dtype=o.compute_dtype,
        similarity=o.similarity, transform=o.transform,
        regularizer=o.regularizer, fused=o.fused)


@functools.lru_cache(maxsize=128)
def compile_level_init(lvl_shape, options):
    """Jitted per-pair lane-state initialiser for one pyramid level.

    ``(phi0, fixed, moving) -> state`` with ``fixed``/``moving`` already at
    this level's resolution (``lvl_shape``) and ``phi0`` the level's starting
    grid (zeros at the coarsest level, the upsampled previous-level grid
    after a migration).  The returned state leaves are unbatched — the
    scheduler splices them into lane ``i`` of its stacked arrays with
    ``jax.tree.map(lambda a, s: a.at[i].set(s), state, lane)``.  Matches
    ``optimize_until``'s init exactly: the gradient at ``phi0`` seeds step 1
    and the initial loss seeds the best-so-far (so a pair the optimiser can
    only make worse retires with its starting params).  The fresh optimiser
    state for ``options.optimizer`` nests under the ``"opt"`` key.
    """
    del lvl_shape  # cache key only; jit re-traces on new shapes anyway
    return jax.jit(functools.partial(_lane_init, options=options))


def _lane_init(phi, f, m, *, options):
    loss0, g0 = _lane_obj(f, m, options).vg(phi)
    i0 = jnp.zeros((), jnp.int32)
    loss0 = loss0.astype(jnp.float32)
    return dict(phi=phi, opt=init_state(options.optimizer, phi), g=g0,
                k=i0, since=i0, best=loss0, best_p=phi, loss=loss0,
                active=jnp.ones((), jnp.bool_))


@functools.lru_cache(maxsize=128)
def compile_level_splice(lvl_shape, options):
    """Jitted lane admission: init one pair AND scatter it into lane ``i``.

    ``(state, F, M, i, phi0, f, m) -> (state, F, M)`` — the fused form of
    ``compile_level_init`` + a per-leaf ``.at[i].set``: one program dispatch
    admits a pair, where leaf-by-leaf host splicing would pay ~a dozen
    dispatches (profiled at ~10ms/admission on CPU, a third of the serving
    wall-clock at small volume sizes).  The stacked operands are donated on
    accelerator backends — the scheduler threads them through every call.
    """
    del lvl_shape  # cache key only

    def splice(state, F, M, i, phi, f, m):
        lane = _lane_init(phi, f, m, options=options)
        state = jax.tree.map(lambda a, s: a.at[i].set(s), state, lane)
        return state, F.at[i].set(f), M.at[i].set(m)

    donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
    return jax.jit(splice, donate_argnums=donate)


@functools.lru_cache(maxsize=128)
def compile_level_chunk(lvl_shape, options, chunk):
    """Jitted ``(state, fixed, moving) -> state``: one chunk of a level.

    Runs ``chunk`` masked optimiser steps (``options.optimizer``) over a
    ``(W, ...)`` lane array at this level's resolution.  Each step
    re-evaluates every lane's liveness — ``active`` (the slot holds a real
    pair) AND ``level_live`` (budget left, patience window open, exactly
    ``optimize_until``'s ``cond``) — and freezes dead lanes by selecting
    their old state, the same per-lane masking the ``while_loop`` batching
    rule applies.  A lane retired mid-chunk therefore holds exactly its
    solo-run result when the state returns to the host, and a freshly
    spliced lane starts its trajectory wherever the chunk boundary fell.
    Rejected second-order steps leave a lane's iterate numerically in place
    (``engine.optimizer.opt_step``), indistinguishable from the masking —
    either way the lane's next live step resumes its exact trajectory.  The
    state argument is donated on accelerator backends (the scheduler
    threads it through every call).

    With ``options.stop`` unset the masking reduces to the fixed-``iters``
    budget and ``tol=-inf`` makes every accepted step "improve", so
    ``best_p`` tracks the current params and the result matches
    ``optimize_scan``.
    """
    del lvl_shape  # cache key only
    o = options
    stop = o.stop
    tol = jnp.float32(stop.tol) if stop is not None else -jnp.inf

    def lane(state, f, m):
        obj = _lane_obj(f, m, o)

        def one(s, _):
            live = jnp.logical_and(
                s["active"],
                level_live(s["k"], s["since"], stop=stop, iters=o.iters))
            k, p, opt, g, loss, since, best, best_p = optimize_plateau_step(
                obj, o.optimizer, s["k"], s["phi"], s["opt"], s["g"],
                s["loss"], s["since"], s["best"], s["best_p"],
                tol=tol, lr=o.lr)
            new = dict(phi=p, opt=opt, g=g, k=k, since=since, best=best,
                       best_p=best_p, loss=loss, active=s["active"])
            return jax.tree.map(
                lambda n, old: jnp.where(live, n, old), new, s), None

        s, _ = jax.lax.scan(one, state, None, length=int(chunk))
        return s

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(jax.vmap(lane), donate_argnums=donate)


@functools.lru_cache(maxsize=64)
def compile_finish(vol_shape, options):
    """Jitted ``(phi, moving) -> warped``: finest grid -> registered volume.

    The same final expansion+warp as ``ffd_pipeline`` (full-resolution BSI of
    the finest-level control grid, then one trilinear warp of the original
    moving volume).
    """
    o = options

    def fin(phi, moving):
        disp = dense_displacement(o.transform, phi, o.tile, vol_shape,
                                  mode=o.mode, impl=o.impl,
                                  grad_impl=o.grad_impl)
        return ffd.warp_volume(moving, disp)

    return jax.jit(fin)
