"""Batched, fully-jitted FFD registration — the "serve heavy traffic" primitive.

``ffd_pipeline`` is the whole multi-level FFD optimisation (pyramid,
scan-based Adam per level, grid upsampling between levels, final warp) as a
pure traced function of ``(fixed, moving)``.  That purity is the point: it
``vmap``s over a leading batch axis, so ``register_batch`` registers N volume
pairs in ONE jitted program — no Python-loop dispatch anywhere, and XLA is
free to batch every BSI expansion, gradient, and Adam update across pairs.

Compiled programs are cached per configuration (shapes x hyperparameters),
so a serving loop pays one compile per volume geometry and then runs
back-to-back batches at device speed.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ffd
from repro.core.similarity import resolve_similarity
from repro.engine.convergence import adam_until, check_stop
from repro.engine.loop import adam_scan

__all__ = ["BatchRegistrationResult", "ffd_level_loss", "ffd_pipeline",
           "register_batch"]


@dataclasses.dataclass
class BatchRegistrationResult:
    warped: Any     # (B, X, Y, Z) registered moving volumes
    params: Any     # (B, *grid_shape, 3) finest-level control grids
    losses: Any     # (B, levels) final loss per pyramid level
    seconds: float  # wall time for the whole batch (see ``compiled``)
    # True when this call (re)compiled the batch program: ``seconds`` then
    # includes the one-time trace+compile and is NOT a steady-state batch
    # time — time a second call (or check this flag) before comparing.
    compiled: bool = False
    # (B, levels) int32 Adam steps actually run per pair per level when the
    # call used early stopping (``stop=``); None under fixed-``iters``.
    steps: Any = None


def ffd_level_loss(f, mov, *, tile, bending_weight, mode, impl,
                   grad_impl="xla", compute_dtype=None, similarity="ssd"):
    """Similarity + bending-energy objective for one pyramid level.

    ``similarity`` is a registered name or a ``(warped, fixed) -> scalar``
    loss callable (lower = better; see ``repro.core.similarity``).  Shared
    verbatim by the per-pair path (``core.registration.ffd_register``) and
    the batched path so the two produce matching optimisations.
    ``grad_impl`` picks the BSI adjoint (``xla`` autodiff vs the analytic
    gather-only custom VJP — see ``repro.core.interpolate``);
    ``compute_dtype`` runs the BSI expansion + warp in reduced precision
    (params, adjoint accumulation and the objective stay fp32).
    """
    vol_shape = f.shape
    _, sim = resolve_similarity(similarity)

    def loss_fn(p):
        disp = ffd.dense_field(p, tile, vol_shape, mode=mode, impl=impl,
                               grad_impl=grad_impl,
                               compute_dtype=compute_dtype)
        warped = ffd.warp_volume(mov, disp, compute_dtype=compute_dtype)
        # score the objective in fp32 regardless of input dtype: casting to
        # f.dtype would silently score a bf16 fixed volume (similarity AND
        # its trade-off against the fp32 bending term) in bf16
        warped = warped.astype(jnp.float32)
        fixed32 = f.astype(jnp.float32)
        return sim(warped, fixed32) + bending_weight * ffd.bending_energy(p)

    return loss_fn


def ffd_pipeline(fixed, moving, *, tile, levels, iters, lr, bending_weight,
                 mode, impl, grad_impl="xla", compute_dtype=None,
                 similarity="ssd", stop=None):
    """Pure multi-level FFD registration of ONE ``(fixed, moving)`` pair.

    Traceable end-to-end (no timing, no host sync): the levels unroll into
    the trace and each level's inner loop is a ``lax.scan`` — or, with a
    resolved ``ConvergenceConfig`` as ``stop``, the early-stopped
    ``lax.while_loop`` (``engine.convergence.adam_until``), under which
    ``vmap``ped lanes freeze as they converge and the level exits when the
    last lane is done.  Returns ``(warped, phi, level_losses)``; with
    ``stop`` set, ``(warped, phi, level_losses, level_steps)`` where
    ``level_steps[l]`` is the Adam steps level ``l`` actually ran.
    """
    pyramid = [(fixed, moving)]
    for _ in range(levels - 1):
        f, m = pyramid[-1]
        pyramid.append((ffd.downsample2(f), ffd.downsample2(m)))
    pyramid = pyramid[::-1]  # coarse -> fine

    phi = None
    finals = []
    steps = []
    for f, m in pyramid:
        gshape = ffd.grid_shape_for_volume(f.shape, tile)
        phi = (jnp.zeros(gshape + (3,), jnp.float32) if phi is None
               else ffd.upsample_grid(phi, gshape))
        loss_fn = ffd_level_loss(f, m, tile=tile,
                                 bending_weight=bending_weight,
                                 mode=mode, impl=impl, grad_impl=grad_impl,
                                 compute_dtype=compute_dtype,
                                 similarity=similarity)
        if stop is None:
            phi, trace = adam_scan(loss_fn, phi, iters=iters, lr=lr)
        else:
            phi, trace, taken = adam_until(loss_fn, phi, stop=stop, lr=lr)
            steps.append(taken)
        finals.append(trace[-1])

    disp = ffd.dense_field(phi, tile, fixed.shape, mode=mode, impl=impl,
                           grad_impl=grad_impl)
    warped = ffd.warp_volume(moving, disp)
    if stop is None:
        return warped, phi, jnp.stack(finals)
    return warped, phi, jnp.stack(finals), jnp.stack(steps)


@functools.lru_cache(maxsize=32)
def _compiled_batch(vol_shape, tile, levels, iters, lr, bending_weight,
                    mode, impl, grad_impl, compute_dtype, similarity,
                    mesh=None, stop=None):
    """One compiled program per (configuration, mesh) — ``mesh`` is part of
    the cache key (``jax.sharding.Mesh`` hashes by devices + axis names), so
    single-device and pod-sharded callers never collide, and two meshes over
    the same devices share a compile.  ``stop`` (a frozen, hashable
    ``ConvergenceConfig`` or None) is part of the key too: the early-stopped
    while-loop program and the fixed-length scan program are different
    programs."""
    del vol_shape  # cache key only; jax re-traces on new shapes anyway
    if mesh is not None:
        from repro.engine.shard import compile_sharded_batch

        return compile_sharded_batch(mesh, tile, levels, iters, lr,
                                     bending_weight, mode, impl, similarity,
                                     grad_impl=grad_impl,
                                     compute_dtype=compute_dtype, stop=stop)

    def single(f, m):
        return ffd_pipeline(f, m, tile=tile, levels=levels, iters=iters,
                            lr=lr, bending_weight=bending_weight,
                            mode=mode, impl=impl, grad_impl=grad_impl,
                            compute_dtype=compute_dtype,
                            similarity=similarity, stop=stop)

    return jax.jit(jax.vmap(single))


def register_batch(fixed, moving, *, tile=(5, 5, 5), levels=2, iters=40,
                   lr=0.5, bending_weight=5e-3, mode="auto", impl="auto",
                   grad_impl="auto", compute_dtype=None, similarity="ssd",
                   mesh=None, stop=None):
    """Register a batch of volume pairs in a single jitted program.

    Args:
      fixed, moving: ``(B, X, Y, Z)`` stacks of volume pairs (B >= 1).
      Remaining args as ``core.registration.ffd_register``;
      ``mode``/``impl``/``grad_impl`` default to ``"auto"`` — the
      ``engine.autotune`` winner for this ``(grid_shape, tile)`` under the
      chosen ``similarity``'s joint forward+backward workload (the adjoint
      axis picks between XLA autodiff and the analytic gather-only custom
      VJP).  ``compute_dtype`` (e.g. ``"bfloat16"``) runs BSI + warp in
      reduced precision with fp32 params/adjoint accumulation.
      ``similarity`` is a registered name (``"ssd" | "ncc" | "lncc" |
      "nmi"``) or a loss callable.
      mesh: optional ``jax.sharding.Mesh`` (see
        ``engine.shard.make_registration_mesh``) — the batch axis shards
        over the mesh's data axes (``REGISTRATION_RULES``), one program
        serving all devices.  Non-divisible batches are padded (repeating
        the last pair) and stripped on return, so results are identical to
        the unsharded path for any B.
      stop: optional ``ConvergenceConfig`` — run each pyramid level as an
        early-stopped ``lax.while_loop`` instead of a fixed-``iters`` scan
        (``stop.max_iters`` defaults to ``iters``).  Converged pairs (and
        ``pad_batch`` filler lanes) freeze — their updates are masked and
        their best-visited params are returned — and the level exits as
        soon as the *last* lane converges, so a batch of easy pairs
        finishes in a fraction of the budget.  Note the SPMD cost model:
        until that exit, frozen lanes still execute the (masked) BSI work,
        so a mixed batch's wall-clock is set by its slowest pair — the
        ``steps`` array the result gains counts optimiser steps per pair
        (quality/accounting), not wall-clock saved.  ``stop=None``
        (default) is the fixed-iteration pipeline, bit-identical to not
        passing ``stop``.

    Returns a :class:`BatchRegistrationResult`; ``warped[b]`` matches what
    per-pair ``ffd_register`` produces for pair ``b``.
    """
    fixed = jnp.asarray(fixed, jnp.float32)
    moving = jnp.asarray(moving, jnp.float32)
    if fixed.ndim != 4:
        raise ValueError(
            f"register_batch expects (B, X, Y, Z) stacks, got {fixed.shape}; "
            "use ffd_register for a single pair")
    if fixed.shape[0] == 0:
        raise ValueError(
            "register_batch got an empty batch (B=0); supply at least one "
            "(fixed, moving) pair")
    if fixed.shape != moving.shape:
        raise ValueError(f"shape mismatch: {fixed.shape} vs {moving.shape}")
    tile = tuple(int(t) for t in tile)
    sim_key, _ = resolve_similarity(similarity)
    compute_dtype = (jnp.dtype(compute_dtype).name
                     if compute_dtype is not None else None)
    stop = check_stop(stop, iters)

    from repro.engine.autotune import resolve_bsi

    # NOTE: the autotune workload pins stop=None — the winner is measured on
    # the fixed-iteration forward+backward BSI step, which is exactly the
    # per-step work an early-stopped loop runs (stopping changes how many
    # steps execute, never which kernel each step should use).
    mode, impl, grad_impl = resolve_bsi(
        mode, impl, ffd.grid_shape_for_volume(fixed.shape[1:], tile), tile,
        grad_impl=grad_impl,  # the adjoint axis is tuned jointly
        measure_grad=True,  # the loop's workload is forward+backward BSI
        similarity=sim_key,  # ... and its backward mix is per-similarity
        compute_dtype=compute_dtype)  # ... measured/cached per dtype

    t0 = time.perf_counter()
    b = fixed.shape[0]
    if mesh is not None:
        from repro.engine.shard import batch_multiple, pad_batch

        fixed, b = pad_batch(fixed, batch_multiple(mesh))
        moving, _ = pad_batch(moving, batch_multiple(mesh))
    misses = _compiled_batch.cache_info().misses
    fn = _compiled_batch(fixed.shape[1:], tile, levels, iters, float(lr),
                         float(bending_weight), mode, impl, grad_impl,
                         compute_dtype, sim_key, mesh, stop)
    compiled = _compiled_batch.cache_info().misses > misses
    out = fn(fixed, moving)
    warped, phi, losses = out[:3]
    steps = out[3] if stop is not None else None
    jax.block_until_ready(warped)
    seconds = time.perf_counter() - t0
    if mesh is not None:  # strip the pad rows (see engine.shard.pad_batch)
        warped, phi, losses = warped[:b], phi[:b], losses[:b]
        steps = steps[:b] if steps is not None else None
    return BatchRegistrationResult(warped, phi, losses, seconds,
                                   compiled=compiled, steps=steps)
