"""Continuous-batching registration serving — an async queue over lane arrays.

``register_batch`` is the throughput primitive for *synchronous* workloads:
N pairs arrive together, one program registers them together, everyone waits
for the slowest pair.  A registration service sees neither of those things —
requests arrive singly (Poisson-ish), with mixed difficulty, and each caller
cares about its own latency.  This module transplants the continuous
batching idea from LLM serving (retire a finished sequence's slot and splice
the next prompt in, instead of waiting for the whole batch) onto the
registration loop, where the per-lane convergence mask of the early-stopped
optimiser loop (``engine.convergence``) is the retire signal:

* Requests are **bucketed by volume shape**: one set of compiled programs
  per bucket (reusing the module-level runner caches in ``engine.batch``),
  so a mixed-geometry stream pays one compile per distinct shape, ever.
* Inside a bucket, each pyramid level is a **stage**: a fixed-width lane
  array of optimiser state driven in ``chunk``-step slices by
  ``engine.batch.compile_level_chunk``.  Stage arrays — rather than a
  per-lane level switch — are the LLM prefill/decode disaggregation move:
  under ``vmap`` a ``lax.switch`` would execute *every* level's branch for
  *every* lane, so one coarse lane would pay fine-level cost; separate
  per-level programs keep each lane paying exactly its level's price.
* After every chunk the state returns to the host; lanes whose convergence
  mask retired mid-chunk are harvested (their state froze at their own
  stopping point, so the result is step-for-step identical to a solo run)
  and queued pairs are **spliced into the freed lanes** — lane recycling.
  Harvested lanes migrate coarse -> fine (grid upsampling, exactly
  ``ffd_register``'s pyramid promotion) and finish with the full-resolution
  warp.

The scheduler is deliberately synchronous and single-threaded — ``step()``
runs one scheduling round, and the caller (the asyncio facade
:class:`AsyncRegistrationService`, the Poisson load generator in
``benchmarks/serving_bench.py``, or a test with a fake clock) owns the
drive loop.  Admission control (``max_queue`` -> :class:`QueueFull`) and
deadlines (``timeout`` -> :class:`RegistrationTimeout`) fail fast and
clean instead of hanging.

The lane programs inherit the full ``RegistrationOptions`` surface through
``engine.batch``'s option-keyed compiles — including the ``transform=``
(diffeomorphic velocity fields), ``regularizer=`` (analytic bending
energy) and ``optimizer=`` (second-order L-BFGS / Gauss-Newton) axes, which
change only the per-lane loss/step/finish programs, not the scheduling
mechanics: the optimiser state nests under the lane dict's ``"opt"`` key and
splices/freezes like any other leaf.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import functools
import itertools
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ffd
from repro.core.options import RegistrationOptions
from repro.engine.batch import (compile_finish, compile_level_chunk,
                                compile_level_splice, level_vol_shapes)
from repro.engine.optimizer import init_state

__all__ = ["QueueFull", "RegistrationTimeout", "ServeResult", "ServeStats",
           "RequestHandle", "RegistrationScheduler",
           "AsyncRegistrationService"]


class QueueFull(RuntimeError):
    """Admission refused: the scheduler's queue is at ``max_queue``.

    Backpressure is the caller's signal to shed load or retry later —
    queueing unboundedly would just convert overload into timeouts.
    """


class RegistrationTimeout(TimeoutError):
    """The request's deadline passed before a lane could take it."""


@dataclasses.dataclass
class ServeResult:
    """One completed registration, as the scheduler hands it back."""

    warped: Any            # (X, Y, Z) registered moving volume
    params: Any            # finest-level control grid (gx, gy, gz, 3)
    losses: list           # final loss per pyramid level (coarse -> fine)
    steps: list            # optimiser steps actually run per level
    seconds: float         # submit -> complete latency (scheduler clock)
    recycled: bool = False # True if any lane was spliced mid-flight


@dataclasses.dataclass
class ServeStats:
    submitted: int = 0
    completed: int = 0
    timed_out: int = 0
    rejected: int = 0      # QueueFull admissions
    recycled: int = 0      # requests that entered a mid-flight stage
    buckets: int = 0       # distinct volume shapes seen
    compiles: int = 0      # distinct compiled stage programs acquired
    chunks: int = 0        # chunk programs dispatched


@dataclasses.dataclass
class RequestHandle:
    """The caller's view of a submitted request.

    Poll ``done`` while driving ``scheduler.step()`` (or let
    :class:`AsyncRegistrationService` do both); then ``result()`` returns
    the :class:`ServeResult` or raises the request's failure
    (:class:`RegistrationTimeout`).
    """

    id: int
    submitted_at: float
    done: bool = False
    _result: Any = None
    _error: Any = None

    def result(self) -> ServeResult:
        if not self.done:
            raise RuntimeError(
                f"request {self.id} is still in flight; drive "
                "scheduler.step() (or use AsyncRegistrationService)")
        if self._error is not None:
            raise self._error
        return self._result


@dataclasses.dataclass
class _Request:
    handle: RequestHandle
    moving: Any                  # full-resolution, for the final warp
    pyramid: Any                 # ((f, m) per level, coarse -> fine)
    deadline: Any                # absolute clock time or None
    phi: Any = None              # carried control grid between levels
    losses: list = dataclasses.field(default_factory=list)
    steps: list = dataclasses.field(default_factory=list)
    recycled: bool = False


class _Stage:
    """One pyramid level's lane array inside a bucket."""

    def __init__(self, level):
        self.level = level
        self.queue = collections.deque()   # _Request waiting to enter
        self.state = None                  # stacked lane state (or None)
        self.fixed = None                  # (W, *lvl_shape)
        self.moving = None
        self.lanes = None                  # list[_Request | None]

    def any_active(self):
        return self.lanes is not None and any(
            r is not None for r in self.lanes)


class _Bucket:
    """All scheduling state for one volume shape."""

    def __init__(self, vol_shape, options):
        self.vol_shape = vol_shape
        self.options = options             # resolved for this shape
        self.lvl_shapes = level_vol_shapes(vol_shape, options.levels)
        self.stages = [_Stage(i) for i in range(options.levels)]


@functools.lru_cache(maxsize=64)
def _pyramid_fn(vol_shape, levels):
    """Jitted ``(f, m) -> ((f_l, m_l), ...)`` pyramid, coarse -> fine."""
    del vol_shape  # cache key only

    def build(f, m):
        levels_fm = [(f, m)]
        for _ in range(levels - 1):
            f, m = levels_fm[-1]
            levels_fm.append((ffd.downsample2(f), ffd.downsample2(m)))
        return tuple(levels_fm[::-1])

    return jax.jit(build)


@functools.lru_cache(maxsize=64)
def _upsample_fn(gshape):
    return jax.jit(lambda p: ffd.upsample_grid(p, gshape))


def _host_live(k, since, stop, iters):
    if stop is None:
        return k < iters
    return (k < stop.max_iters) and (since < stop.patience)


class RegistrationScheduler:
    """Continuous-batching scheduler for registration requests.

    Args:
      options: the ``RegistrationOptions`` every request runs under (the
        service analogue of a model checkpoint: one configuration per
        scheduler; buckets only vary by volume shape).
      lanes: lane-array width per stage — the in-flight pair capacity of
        each pyramid level.  With ``mesh=``, must be a multiple of
        ``engine.shard.batch_multiple(mesh)``.
      chunk: optimiser steps per scheduling slice.  Smaller -> finer recycling
        granularity (lower queue latency) but more host round-trips;
        ``chunk`` never affects results, only when the host looks.
      max_queue: admission bound on waiting requests (across buckets);
        ``submit`` raises :class:`QueueFull` beyond it.
      timeout: default per-request seconds from submit until the request
        must have *completed*; expired requests fail with
        :class:`RegistrationTimeout` at the next round boundary (a round's
        device work is never interrupted mid-chunk).
      mesh: optional ``jax.sharding.Mesh`` — lane arrays shard batch-over-
        data (``engine.shard.lane_sharding``), one chunk program driving
        all devices.
      clock: injectable monotonic-seconds source (tests use a fake clock to
        exercise deadlines deterministically).
    """

    def __init__(self, options=None, *, lanes=8, chunk=4, max_queue=64,
                 timeout=None, mesh=None, clock=time.monotonic):
        if options is None:
            options = RegistrationOptions()
        if not isinstance(options, RegistrationOptions):
            raise TypeError(
                f"options must be a RegistrationOptions, got "
                f"{type(options).__name__}")
        if lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if mesh is not None:
            from repro.engine.shard import batch_multiple

            mult = batch_multiple(mesh)
            if lanes % mult:
                raise ValueError(
                    f"lanes={lanes} must be a multiple of the mesh's batch "
                    f"multiple ({mult}) for an even lane split")
        self.options = options
        self.lanes = int(lanes)
        self.chunk = int(chunk)
        self.max_queue = int(max_queue)
        self.timeout = timeout
        self.mesh = mesh
        self.clock = clock
        self.stats = ServeStats()
        self._buckets: dict = {}
        self._ids = itertools.count()
        self._queued = 0              # waiting (not yet in a lane)
        self._inflight = 0            # in a lane somewhere
        self._programs: set = set()   # distinct stage-program keys acquired

    # -- submission ---------------------------------------------------------

    def submit(self, fixed, moving, *, timeout=None) -> RequestHandle:
        """Queue one ``(fixed, moving)`` pair; returns immediately.

        Raises :class:`QueueFull` when ``max_queue`` requests are already
        waiting.  The pair's pyramid is built (on device) at submission so
        admission into a freed lane is a pure splice.
        """
        fixed = jnp.asarray(fixed, jnp.float32)
        moving = jnp.asarray(moving, jnp.float32)
        if fixed.ndim != 3 or fixed.shape != moving.shape:
            raise ValueError(
                "submit expects one (X, Y, Z) pair of equal shapes, got "
                f"{fixed.shape} vs {moving.shape}")
        if self._queued >= self.max_queue:
            self.stats.rejected += 1
            raise QueueFull(
                f"{self._queued} requests waiting (max_queue="
                f"{self.max_queue}); retry later or raise max_queue")
        bucket = self._bucket_for(fixed.shape)
        now = self.clock()
        timeout = self.timeout if timeout is None else timeout
        handle = RequestHandle(id=next(self._ids), submitted_at=now)
        req = _Request(
            handle=handle, moving=moving,
            pyramid=_pyramid_fn(fixed.shape, bucket.options.levels)(
                fixed, moving),
            deadline=None if timeout is None else now + float(timeout))
        bucket.stages[0].queue.append(req)
        self._queued += 1
        self.stats.submitted += 1
        return handle

    def _bucket_for(self, vol_shape) -> _Bucket:
        bucket = self._buckets.get(vol_shape)
        if bucket is None:
            from repro.engine.autotune import resolve_options

            bucket = _Bucket(vol_shape, resolve_options(self.options,
                                                        vol_shape))
            self._buckets[vol_shape] = bucket
            self.stats.buckets += 1
        return bucket

    # -- the scheduling round ----------------------------------------------

    def step(self) -> int:
        """One scheduling round over every bucket; returns completions.

        Per stage, coarse -> fine: expire dead queue entries, splice queued
        pairs into free lanes, run one ``chunk`` of masked optimiser
        steps, then harvest lanes whose convergence mask retired — migrating them to
        the next stage's queue (so a pair can traverse one stage per round)
        or finishing with the full-resolution warp.
        """
        done = 0
        for bucket in self._buckets.values():
            ran = []
            # dispatch every stage's chunk before the first (blocking)
            # harvest: the chunks execute asynchronously, so the coarse and
            # fine programs overlap instead of serialising on each sync
            for stage in bucket.stages:
                self._expire(stage)
                self._fill(bucket, stage)
                if not stage.any_active():
                    continue
                key = (bucket.lvl_shapes[stage.level], bucket.options,
                       self.chunk)
                if key not in self._programs:
                    self._programs.add(key)
                    self.stats.compiles += 1
                fn = compile_level_chunk(*key)
                stage.state = fn(stage.state, stage.fixed, stage.moving)
                self.stats.chunks += 1
                ran.append(stage)
            for stage in ran:
                done += self._harvest(bucket, stage)
        return done

    def run_until_idle(self, max_rounds=100_000) -> int:
        """Drive ``step()`` until no request is waiting or in flight."""
        done = 0
        for _ in range(max_rounds):
            if not self.pending:
                return done
            done += self.step()
        raise RuntimeError(
            f"still {self._queued} queued / {self._inflight} in flight "
            f"after {max_rounds} rounds — is the clock advancing?")

    @property
    def pending(self) -> int:
        """Requests not yet completed (waiting + in a lane)."""
        return self._queued + self._inflight

    # -- internals ----------------------------------------------------------

    def _expire(self, stage):
        now = self.clock()
        keep = collections.deque()
        for req in stage.queue:
            if req.deadline is not None and now >= req.deadline:
                if stage.level == 0:  # migration queues hold in-flight work
                    self._queued -= 1
                else:
                    self._inflight -= 1
                self.stats.timed_out += 1
                req.handle._error = RegistrationTimeout(
                    f"request {req.handle.id} expired after "
                    f"{now - req.handle.submitted_at:.3f}s waiting for a "
                    "lane")
                req.handle.done = True
            else:
                keep.append(req)
        stage.queue = keep

    def _alloc(self, bucket, stage, lvl_shape):
        """Allocate the stage's stacked lane arrays (all lanes inactive)."""
        W = self.lanes
        gshape = ffd.grid_shape_for_volume(lvl_shape, bucket.options.tile)
        grid = gshape + (3,)
        zg = jnp.zeros((W,) + grid, jnp.float32)
        zi = jnp.zeros((W,), jnp.int32)
        zf = jnp.zeros((W,), jnp.float32)
        # the optimiser state's lane template comes from the registry, so a
        # new optimiser's lanes allocate (and shard) without touching the
        # scheduler: every leaf is stacked to a leading (W, ...) lane axis
        opt = jax.tree.map(
            lambda a: jnp.zeros((W,) + a.shape, a.dtype),
            init_state(bucket.options.optimizer, jnp.zeros(grid,
                                                           jnp.float32)))
        state = dict(phi=zg, opt=opt, g=zg, best_p=zg, k=zi, since=zi,
                     best=zf, loss=zf, active=jnp.zeros((W,), jnp.bool_))
        stage.fixed = jnp.zeros((W,) + lvl_shape, jnp.float32)
        stage.moving = jnp.zeros((W,) + lvl_shape, jnp.float32)
        stage.lanes = [None] * W
        if self.mesh is not None:
            from repro.engine.shard import lane_sharding

            sh = lane_sharding(self.mesh)
            state = jax.device_put(state, sh)
            stage.fixed = jax.device_put(stage.fixed, sh)
            stage.moving = jax.device_put(stage.moving, sh)
        stage.state = state

    def _fill(self, bucket, stage):
        if not stage.queue:
            return
        lvl_shape = bucket.lvl_shapes[stage.level]
        splice = compile_level_splice(lvl_shape, bucket.options)
        mid_flight = stage.any_active()
        if stage.lanes is None:
            self._alloc(bucket, stage, lvl_shape)
        for i, slot in enumerate(stage.lanes):
            if slot is not None:
                continue
            if not stage.queue:
                break
            req = stage.queue.popleft()
            f, m = req.pyramid[stage.level]
            if req.phi is None:  # coarsest level starts from the zero grid
                gshape = ffd.grid_shape_for_volume(lvl_shape,
                                                   bucket.options.tile)
                req.phi = jnp.zeros(gshape + (3,), jnp.float32)
            stage.state, stage.fixed, stage.moving = splice(
                stage.state, stage.fixed, stage.moving, i, req.phi, f, m)
            stage.lanes[i] = req
            if stage.level == 0:
                self._queued -= 1
                self._inflight += 1
            if mid_flight and not req.recycled:
                req.recycled = True
                self.stats.recycled += 1

    def _harvest(self, bucket, stage) -> int:
        opts = bucket.options
        host = jax.device_get({k: stage.state[k]
                               for k in ("k", "since", "active", "best")})
        done = 0
        retired = []
        for i, req in enumerate(stage.lanes):
            if req is None or not bool(host["active"][i]):
                continue
            if _host_live(int(host["k"][i]), int(host["since"][i]),
                          opts.stop, opts.iters):
                continue
            # retired: its carry froze at the stopping point, so best_p is
            # exactly the solo optimize_until result
            req.phi = stage.state["best_p"][i]
            req.losses.append(float(host["best"][i]))
            req.steps.append(int(host["k"][i]))
            stage.lanes[i] = None
            retired.append(i)
            if stage.level + 1 < opts.levels:
                next_g = ffd.grid_shape_for_volume(
                    bucket.lvl_shapes[stage.level + 1], opts.tile)
                req.phi = _upsample_fn(next_g)(req.phi)
                bucket.stages[stage.level + 1].queue.append(req)
            else:
                self._finish(bucket, req)
                done += 1
        if retired:  # one fused clear instead of a dispatch per lane
            stage.state["active"] = stage.state["active"].at[
                jnp.asarray(retired)].set(False)
        return done

    def _finish(self, bucket, req):
        warped = compile_finish(bucket.vol_shape, bucket.options)(
            req.phi, req.moving)
        handle = req.handle
        handle._result = ServeResult(
            warped=warped, params=req.phi, losses=req.losses,
            steps=req.steps,
            seconds=self.clock() - handle.submitted_at,
            recycled=req.recycled)
        handle.done = True
        self._inflight -= 1
        self.stats.completed += 1


class AsyncRegistrationService:
    """Asyncio facade: ``await service.register(fixed, moving)``.

    A thin drive loop over :class:`RegistrationScheduler` — concurrent
    ``register`` calls share the scheduler through a lock, each pumping
    ``step()`` (in the default executor, so the event loop stays live
    while the device works) until its own request completes.  Admission
    and deadline failures surface as the scheduler's exceptions.
    """

    def __init__(self, scheduler=None, **scheduler_kwargs):
        self.scheduler = (RegistrationScheduler(**scheduler_kwargs)
                          if scheduler is None else scheduler)
        self._lock = asyncio.Lock()

    async def register(self, fixed, moving, *, timeout=None) -> ServeResult:
        handle = self.scheduler.submit(fixed, moving, timeout=timeout)
        loop = asyncio.get_running_loop()
        while not handle.done:
            async with self._lock:
                if not handle.done:
                    await loop.run_in_executor(None, self.scheduler.step)
            await asyncio.sleep(0)  # let other registrations interleave
        return handle.result()
