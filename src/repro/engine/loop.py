"""Device-resident optimisation loops: ``jax.lax.scan`` over Adam steps.

The seed drove every optimiser from a Python ``for`` loop — one XLA dispatch
per step, per-call re-jits (the ``step_fn`` closure was redefined on every
``ffd_register`` call), and a host round-trip between steps.  Budelmann et
al. and Brunn et al. (PAPERS.md) get their registration wall-clock wins from
keeping the whole loop resident on the accelerator; this module is that loop:

* ``adam_scan`` — the pure form: ``iters`` Adam steps as a single
  ``lax.scan``, traceable, so it nests under ``jax.vmap`` (the batched
  engine) and under an outer ``jit`` (one compile per pyramid level).
* ``make_adam_runner`` — the compiled form: a jitted runner whose
  ``(params, m, v)`` buffers are donated on accelerator backends, and whose
  data operands are arguments (not closures) so one compile serves every
  call with the same shapes.  ``stop=`` swaps the fixed-length scan for the
  early-stopped ``lax.while_loop`` (``engine.convergence.adam_until``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.options import UNSET, RegistrationOptions, merge_legacy_options
from repro.engine.convergence import adam_update, adam_until, check_stop

__all__ = ["adam_scan", "make_adam_runner"]


def adam_scan(loss_fn, params, *, iters, lr, b1=0.9, b2=0.999, eps=1e-8,
              m=None, v=None):
    """Run ``iters`` Adam steps on ``loss_fn`` as one ``lax.scan``.

    Pure function of its inputs (no jit inside) so it composes with
    ``jax.jit`` / ``jax.vmap`` at the call site.

    Returns ``(params, trace)`` where ``trace[k]`` is the loss after ``k+1``
    updates (same convention as evaluating the loss after each step of the
    seed's Python loop).  Each step applies the update *first* and then
    evaluates ``value_and_grad`` at the new params — the loss closes the
    step's own trace slot and the gradient seeds the next step — the same
    step shape as the early-stopped ``engine.convergence.adam_until``, so
    the two trajectories match step for step.  The former separate
    trace-closing forward pass (``loss_fn(p)`` after the scan) is gone; its
    cost moved into the final step's in-scan evaluation, whose gradient is
    unused (a forward traded for a backward — a wash under the analytic
    gather adjoint, where the two cost about the same).
    """
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    m = jnp.zeros_like(params) if m is None else m
    v = jnp.zeros_like(params) if v is None else v

    vg = jax.value_and_grad(loss_fn)
    _, g0 = vg(params)  # gradient at the initial params seeds step 1

    def step(carry, i):
        p, m, v, g = carry
        p, m, v = adam_update(p, m, v, g, i, lr=lr, b1=b1, b2=b2, eps=eps)
        loss, g = vg(p)  # post-update loss = this step's trace entry
        return (p, m, v, g), loss

    steps = jnp.arange(1, iters + 1, dtype=jnp.float32)
    (p, _, _, _), trace = jax.lax.scan(step, (params, m, v, g0), steps)
    return p, trace


def make_adam_runner(loss_builder, *, options=None, iters=UNSET, lr=UNSET,
                     b1=0.9, b2=0.999, eps=1e-8, donate=None, stop=UNSET):
    """Build a jitted ``(params, m, v, *data) -> ...`` runner.

    ``loss_builder(*data)`` returns the scalar loss function of the params;
    the data arrays travel through jit as arguments, so callers that cache
    the runner (e.g. by shape) pay one compile per configuration, not per
    call.  ``(params, m, v)`` are donated unless ``donate=False`` (donation
    is skipped on CPU, where XLA cannot honour it and only warns).

    The loop hyperparameters come from ``options=`` (a
    ``repro.core.RegistrationOptions`` — only its ``iters`` / ``lr`` /
    ``stop`` fields apply here); the legacy ``iters=`` / ``lr=`` / ``stop=``
    keywords still work via the deprecation shim.  ``b1``/``b2``/``eps`` and
    ``donate`` are loop-level knobs outside the options object.

    With no stopping rule the runner is the fixed-length scan and returns
    ``(params, trace)``.  With a ``ConvergenceConfig`` it runs
    ``adam_until`` instead and returns ``(params, trace, steps_taken)`` —
    the trace padded to ``stop.max_iters`` (see ``engine.convergence``).
    """
    if options is None and (iters is UNSET or lr is UNSET):
        raise TypeError(
            "make_adam_runner needs options=RegistrationOptions(...) or the "
            "legacy iters=/lr= keywords")
    opts = merge_legacy_options(
        "make_adam_runner", options,
        dict(iters=iters, lr=lr, stop=stop),
        defaults=RegistrationOptions())
    iters, lr = opts.iters, opts.lr
    if donate is None:
        donate = jax.default_backend() != "cpu"
    stop = check_stop(opts.stop, iters)

    def run(p, m, v, *data):
        loss_fn = loss_builder(*data)
        if stop is None:
            return adam_scan(loss_fn, p, iters=iters, lr=lr,
                             b1=b1, b2=b2, eps=eps, m=m, v=v)
        return adam_until(loss_fn, p, stop=stop, lr=lr,
                          b1=b1, b2=b2, eps=eps, m=m, v=v)

    return jax.jit(run, donate_argnums=(0, 1, 2) if donate else ())
