"""Device-resident optimisation loops: ``jax.lax.scan`` over optimiser steps.

The seed drove every optimiser from a Python ``for`` loop — one XLA dispatch
per step, per-call re-jits (the ``step_fn`` closure was redefined on every
``ffd_register`` call), and a host round-trip between steps.  Budelmann et
al. and Brunn et al. (PAPERS.md) get their registration wall-clock wins from
keeping the whole loop resident on the accelerator; this module is that loop:

* ``optimize_scan`` — the pure form, generic over the ``optimizer=``
  registry (``engine.optimizer``): ``iters`` optimiser steps as a single
  ``lax.scan``, traceable, so it nests under ``jax.vmap`` (the batched
  engine) and under an outer ``jit`` (one compile per pyramid level).
  ``adam_scan`` is its historical Adam face, kept verbatim as the
  bit-identity anchor the parity tests compare against.
* ``make_adam_runner`` — the compiled form: a jitted runner whose params
  buffer is donated on accelerator backends, and whose data operands are
  arguments (not closures) so one compile serves every call with the same
  shapes.  The optimiser comes from ``options.optimizer`` (default
  ``"adam"``); ``stop=`` swaps the fixed-length scan for the early-stopped
  ``lax.while_loop`` (``engine.convergence.optimize_until``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.options import UNSET, RegistrationOptions, merge_legacy_options
from repro.engine.convergence import adam_update, check_stop, optimize_until
from repro.engine.optimizer import (AdamOptimizer, Objective, init_state,
                                    make_objective, opt_step,
                                    resolve_optimizer)

__all__ = ["adam_scan", "make_adam_runner", "optimize_scan"]


def adam_scan(loss_fn, params, *, iters, lr, b1=0.9, b2=0.999, eps=1e-8,
              m=None, v=None):
    """Run ``iters`` Adam steps on ``loss_fn`` as one ``lax.scan``.

    Pure function of its inputs (no jit inside) so it composes with
    ``jax.jit`` / ``jax.vmap`` at the call site.

    Returns ``(params, trace)`` where ``trace[k]`` is the loss after ``k+1``
    updates (same convention as evaluating the loss after each step of the
    seed's Python loop).  Each step applies the update *first* and then
    evaluates ``value_and_grad`` at the new params — the loss closes the
    step's own trace slot and the gradient seeds the next step — the same
    step shape as the early-stopped ``engine.convergence.adam_until``, so
    the two trajectories match step for step.  Kept as the literal seed
    loop (not routed through the optimiser registry) on purpose: it is the
    bit-identity anchor ``tests/test_optimizer.py`` compares the registry's
    ``adam`` entry against.
    """
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    m = jnp.zeros_like(params) if m is None else m
    v = jnp.zeros_like(params) if v is None else v

    vg = jax.value_and_grad(loss_fn)
    _, g0 = vg(params)  # gradient at the initial params seeds step 1

    def step(carry, i):
        p, m, v, g = carry
        p, m, v = adam_update(p, m, v, g, i, lr=lr, b1=b1, b2=b2, eps=eps)
        loss, g = vg(p)  # post-update loss = this step's trace entry
        return (p, m, v, g), loss

    steps = jnp.arange(1, iters + 1, dtype=jnp.float32)
    (p, _, _, _), trace = jax.lax.scan(step, (params, m, v, g0), steps)
    return p, trace


def optimize_scan(obj, params, *, optimizer, iters, lr, opt=None):
    """Run ``iters`` steps of a registered optimiser as one ``lax.scan``.

    The registry-generic form of :func:`adam_scan`: same trace convention
    (``trace[k]`` is the loss after ``k+1`` steps), same purity (composes
    with ``jit``/``vmap`` at the call site), but the per-step arithmetic is
    ``engine.optimizer.opt_step`` on an ``Objective`` — with
    ``optimizer="adam"`` the trajectory is bit-identical to
    :func:`adam_scan`.  Rejected second-order steps (collapsed line search,
    refused LM trial) leave the iterate in place for that slot; the fixed
    budget keeps scanning either way.  Returns ``(params, trace)``.
    """
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    spec = resolve_optimizer(optimizer)
    opt = init_state(spec, params) if opt is None else opt

    loss0, g0 = obj.vg(params)  # gradient at the initial params seeds step 1
    loss0 = loss0.astype(jnp.float32)

    def step(carry, k):
        p, opt, g, loss = carry
        p, opt, g, loss, _ = opt_step(spec, obj, k, p, opt, g, loss, lr=lr)
        return (p, opt, g, loss), loss

    ks = jnp.arange(iters, dtype=jnp.int32)
    (p, _, _, _), trace = jax.lax.scan(step, (params, opt, g0, loss0), ks)
    return p, trace


def make_adam_runner(loss_builder, *, options=None, iters=UNSET, lr=UNSET,
                     b1=0.9, b2=0.999, eps=1e-8, donate=None, stop=UNSET,
                     optimizer=UNSET):
    """Build a jitted ``(params, *data) -> ...`` runner.

    ``loss_builder(*data)`` returns the scalar loss function of the params
    — or a full ``engine.optimizer.Objective`` (needed for residual-form
    optimisers like ``gauss_newton``); the data arrays travel through jit
    as arguments, so callers that cache the runner (e.g. by shape) pay one
    compile per configuration, not per call.  The optimiser state is built
    inside the program (``init_state``), so the runner takes only the
    params; ``params`` is donated unless ``donate=False`` (donation is
    skipped on CPU, where XLA cannot honour it and only warns).

    The loop hyperparameters come from ``options=`` (a
    ``repro.core.RegistrationOptions`` — its ``iters`` / ``lr`` / ``stop``
    / ``optimizer`` fields apply here); the legacy ``iters=`` / ``lr=`` /
    ``stop=`` / ``optimizer=`` keywords still work via the deprecation
    shim.  ``b1``/``b2``/``eps`` are Adam-only knobs outside the options
    object (ignored by the second-order entries, which fold their own
    hyperparameters into their specs); ``donate`` stays a loop-level knob.

    With no stopping rule the runner is the fixed-length scan and returns
    ``(params, trace)``.  With a ``ConvergenceConfig`` it runs
    ``optimize_until`` instead and returns ``(params, trace, steps_taken)``
    — the trace padded to ``stop.max_iters`` (see ``engine.convergence``).
    """
    if options is None and (iters is UNSET or lr is UNSET):
        raise TypeError(
            "make_adam_runner needs options=RegistrationOptions(...) or the "
            "legacy iters=/lr= keywords")
    opts = merge_legacy_options(
        "make_adam_runner", options,
        dict(iters=iters, lr=lr, stop=stop, optimizer=optimizer),
        defaults=RegistrationOptions())
    iters, lr = opts.iters, opts.lr
    spec = resolve_optimizer(opts.optimizer)
    if isinstance(spec, AdamOptimizer) and spec == AdamOptimizer():
        # fold the legacy Adam knobs into the spec (defaults are a no-op)
        spec = AdamOptimizer(b1=b1, b2=b2, eps=eps)
    if donate is None:
        donate = jax.default_backend() != "cpu"
    stop = check_stop(opts.stop, iters)

    def run(p, *data):
        built = loss_builder(*data)
        obj = built if isinstance(built, Objective) else make_objective(built)
        if stop is None:
            return optimize_scan(obj, p, optimizer=spec, iters=iters, lr=lr)
        return optimize_until(obj, p, optimizer=spec, stop=stop, lr=lr)

    return jax.jit(run, donate_argnums=(0,) if donate else ())
