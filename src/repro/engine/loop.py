"""Device-resident optimisation loops: ``jax.lax.scan`` over Adam steps.

The seed drove every optimiser from a Python ``for`` loop — one XLA dispatch
per step, per-call re-jits (the ``step_fn`` closure was redefined on every
``ffd_register`` call), and a host round-trip between steps.  Budelmann et
al. and Brunn et al. (PAPERS.md) get their registration wall-clock wins from
keeping the whole loop resident on the accelerator; this module is that loop:

* ``adam_scan`` — the pure form: ``iters`` Adam steps as a single
  ``lax.scan``, traceable, so it nests under ``jax.vmap`` (the batched
  engine) and under an outer ``jit`` (one compile per pyramid level).
* ``make_adam_runner`` — the compiled form: a jitted runner whose
  ``(params, m, v)`` buffers are donated on accelerator backends, and whose
  data operands are arguments (not closures) so one compile serves every
  call with the same shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adam_scan", "make_adam_runner"]


def adam_scan(loss_fn, params, *, iters, lr, b1=0.9, b2=0.999, eps=1e-8,
              m=None, v=None):
    """Run ``iters`` Adam steps on ``loss_fn`` as one ``lax.scan``.

    Pure function of its inputs (no jit inside) so it composes with
    ``jax.jit`` / ``jax.vmap`` at the call site.

    Returns ``(params, trace)`` where ``trace[k]`` is the loss after ``k+1``
    updates (same convention as evaluating the loss after each step of the
    seed's Python loop).  The final trace entry costs one extra forward pass;
    the per-step entries reuse the forward already needed for the gradient.
    """
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    m = jnp.zeros_like(params) if m is None else m
    v = jnp.zeros_like(params) if v is None else v

    def step(carry, i):
        p, m, v = carry
        loss, g = jax.value_and_grad(loss_fn)(p)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**i)
        vh = v / (1 - b2**i)
        return (p - lr * mh / (jnp.sqrt(vh) + eps), m, v), loss

    steps = jnp.arange(1, iters + 1, dtype=jnp.float32)
    (p, _, _), pre = jax.lax.scan(step, (params, m, v), steps)
    # pre[k] = loss *before* update k+1; shift by one and close with the
    # final loss so trace[k] = loss after k+1 updates.
    trace = jnp.concatenate([pre[1:], loss_fn(p)[None]])
    return p, trace


def make_adam_runner(loss_builder, *, iters, lr, b1=0.9, b2=0.999, eps=1e-8,
                     donate=None):
    """Build a jitted ``(params, m, v, *data) -> (params, trace)`` runner.

    ``loss_builder(*data)`` returns the scalar loss function of the params;
    the data arrays travel through jit as arguments, so callers that cache
    the runner (e.g. by shape) pay one compile per configuration, not per
    call.  ``(params, m, v)`` are donated unless ``donate=False`` (donation
    is skipped on CPU, where XLA cannot honour it and only warns).
    """
    if donate is None:
        donate = jax.default_backend() != "cpu"

    def run(p, m, v, *data):
        return adam_scan(loss_builder(*data), p, iters=iters, lr=lr,
                         b1=b1, b2=b2, eps=eps, m=m, v=v)

    return jax.jit(run, donate_argnums=(0, 1, 2) if donate else ())
