"""Deterministic, resumable, sharded synthetic token pipeline.

Production posture (DESIGN.md §5): each host generates only its shard of
the global batch (``host_id``/``num_hosts``), batches are a pure function
of ``(seed, step)`` so *any* host can regenerate *any* step — which makes
the pipeline trivially resumable after preemption (state = one integer)
and immune to data-order divergence across restarts.  The token stream is
a mixture of Zipf-distributed unigrams and short Markov motifs so the loss
has learnable structure (used by the e2e example to show loss descent).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PipelineConfig", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    n_motifs: int = 64
    motif_len: int = 8


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(cfg.seed)
        # fixed motif table (shared across hosts: same seed)
        self.motifs = rng.integers(
            0, cfg.vocab_size, (cfg.n_motifs, cfg.motif_len), dtype=np.int32
        )
        # Zipf-ish unigram distribution
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks**1.1
        self.unigram = p / p.sum()

    def state(self, step: int) -> dict:
        return {"step": int(step), "seed": self.cfg.seed}

    def batch_at(self, step: int) -> dict:
        """The (host-local) batch for ``step`` — pure function of inputs."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + step) * 4096 + c.host_id
        )
        toks = rng.choice(
            c.vocab_size, size=(self.local_batch, c.seq_len + 1),
            p=self.unigram,
        ).astype(np.int32)
        # plant motifs: ~25% of positions covered by copyable structure
        n_plant = (self.local_batch * (c.seq_len + 1)) // (4 * c.motif_len)
        rows = rng.integers(0, self.local_batch, n_plant)
        cols = rng.integers(0, c.seq_len + 1 - c.motif_len, n_plant)
        ids = rng.integers(0, c.n_motifs, n_plant)
        for r, col, i in zip(rows, cols, ids):
            toks[r, col : col + c.motif_len] = self.motifs[i]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
