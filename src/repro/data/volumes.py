"""Synthetic pre-clinical volume generator (stand-in for the paper's dataset).

The paper's dataset (Mendeley, liver phantom DynaCT + porcine MRI) is not
shipped offline, so we synthesise anatomically-flavoured volumes with the same
*structure* the evaluation needs: a smooth parenchyma blob, tumour spheres and
vessel tubes (paper §4), plus a known smooth non-rigid deformation ("pneumo-
peritoneum") to create registration pairs.  Shapes default to scaled-down
versions of paper Table 2; the exact table shapes are available via
``PAPER_VOLUMES`` for the dry-run / roofline path (no allocation needed).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ffd

__all__ = ["PAPER_VOLUMES", "make_phantom", "make_pair"]

# Paper Table 2: registration pair -> resolution (voxels).
PAPER_VOLUMES = {
    "phantom1": (512, 228, 385),
    "phantom2": (294, 130, 208),
    "phantom3": (294, 130, 208),
    "porcine1": (303, 167, 212),
    "porcine2": (267, 169, 237),
}


def make_phantom(shape=(72, 64, 56), *, n_tumors=5, n_vessels=3, seed=0):
    """Liver-phantom-like volume: ellipsoid parenchyma + tumours + vessels."""
    rng = np.random.default_rng(seed)
    X, Y, Z = shape
    xs, ys, zs = np.meshgrid(
        np.linspace(-1, 1, X), np.linspace(-1, 1, Y), np.linspace(-1, 1, Z),
        indexing="ij",
    )
    # parenchyma: soft ellipsoid with a lobed boundary
    r2 = (xs / 0.8) ** 2 + (ys / 0.7) ** 2 + (zs / 0.75) ** 2
    lobes = 0.12 * np.sin(3 * xs + 1.0) * np.cos(2 * ys)
    vol = 0.55 * (1.0 / (1.0 + np.exp(40 * (r2 - 0.8 + lobes))))
    # tumours: bright spheres inside the parenchyma
    for _ in range(n_tumors):
        c = rng.uniform(-0.45, 0.45, 3)
        rad = rng.uniform(0.06, 0.14)
        d2 = (xs - c[0]) ** 2 + (ys - c[1]) ** 2 + (zs - c[2]) ** 2
        vol += 0.35 * np.exp(-d2 / (2 * rad**2))
    # vessels: bright tubes along random directions
    for _ in range(n_vessels):
        p = rng.uniform(-0.35, 0.35, 3)
        d = rng.standard_normal(3)
        d /= np.linalg.norm(d)
        rel = np.stack([xs - p[0], ys - p[1], zs - p[2]], -1)
        t = rel @ d
        closest = rel - t[..., None] * d
        dist2 = (closest**2).sum(-1)
        vol += 0.25 * np.exp(-dist2 / (2 * 0.03**2)) * (np.abs(t) < 0.6)
    vol += rng.normal(0.0, 0.01, vol.shape)  # acquisition noise
    return jnp.asarray(np.clip(vol, 0.0, 1.0), jnp.float32)


def make_pair(shape=(72, 64, 56), *, tile=(6, 6, 6), magnitude=2.5, seed=0):
    """A (fixed, moving) registration pair with a known FFD deformation.

    The *fixed* volume is the phantom; the *moving* volume is the phantom
    warped by a random smooth control grid (the synthetic pneumoperitoneum),
    i.e. ground-truth recoverable by FFD registration.
    """
    fixed = make_phantom(shape, seed=seed)
    rng = np.random.default_rng(seed + 1)
    gshape = ffd.grid_shape_for_volume(shape, tile)
    phi_true = jnp.asarray(
        rng.normal(0.0, magnitude, gshape + (3,)), jnp.float32
    )
    disp = ffd.dense_field(phi_true, tile, shape)
    moving = ffd.warp_volume(fixed, disp)
    return fixed, moving, phi_true
