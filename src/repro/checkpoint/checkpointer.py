"""Fault-tolerant checkpointing: atomic, versioned, resharding-on-restore.

Design for 1000+-node operation (DESIGN.md §5):

* **atomic** — write to ``step_N.tmp/``, fsync, rename; a crash mid-save
  never corrupts the latest checkpoint;
* **versioned + keep-k** — old checkpoints garbage-collected, the manifest
  carries a content hash so truncated files are detected at restore;
* **resharding restore** — arrays are saved unsharded (gathered per leaf),
  so a checkpoint taken on one mesh restores onto *any* mesh/topology —
  this is the elastic-restart path after losing a pod (tested by saving
  and restoring across different device counts);
* **async** — ``save(..., blocking=False)`` hands the host copy to a
  writer thread so the train loop continues;
* **preemption hook** — ``install_signal_handler`` saves on SIGTERM.

Storage is one ``.npz`` per checkpoint plus a JSON manifest; leaf paths are
flattened pytree keys.  (No orbax dependency — the container is offline.)
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class Checkpointer:
    def __init__(self, directory, keep=3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread = None

    # ------------------------------------------------------------- save

    def save(self, step: int, state, extra: dict | None = None,
             blocking: bool = True):
        """Snapshot ``state`` (pytree of arrays) at ``step``."""
        host = {k: np.asarray(v) for k, v in _flatten(state).items()}
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time
        if blocking:
            self._write(step, host, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}))
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host, extra):
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        npz = tmp / "arrays.npz"
        np.savez(npz, **{k: v for k, v in host.items()})
        digest = hashlib.sha256(npz.read_bytes()).hexdigest()
        manifest = {
            "step": step,
            "time": time.time(),
            "sha256": digest,
            "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in host.items()},
            "extra": extra,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def _gc(self):
        ckpts = sorted(self.all_steps())
        for s in ckpts[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self):
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        ]

    def latest_step(self):
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, like, step: int | None = None, shardings=None):
        """Restore into the structure of ``like`` (pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings — arrays are placed (and thus resharded) onto them,
        which is how a checkpoint from mesh A restarts on mesh B."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        final = self.dir / f"step_{step:09d}"
        manifest = json.loads((final / "manifest.json").read_text())
        npz_path = final / "arrays.npz"
        digest = hashlib.sha256(npz_path.read_bytes()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {final} corrupt (hash mismatch)")
        data = np.load(npz_path)

        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves, treedef = flat_like
        sh_flat = (
            {jax.tree_util.keystr(p): s
             for p, s in jax.tree_util.tree_flatten_with_path(shardings)[0]}
            if shardings is not None else {}
        )
        out = []
        for path, leaf in leaves:
            key = jax.tree_util.keystr(path)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
            if key in sh_flat:
                out.append(jax.device_put(arr, sh_flat[key]))
            else:
                out.append(jnp.asarray(arr))
        extra = manifest.get("extra", {})
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out), step, extra

    # ------------------------------------------------------ preemption

    def install_signal_handler(self, get_state, get_step):
        """Save a final checkpoint on SIGTERM/SIGINT (preemption notice)."""
        def handler(signum, frame):
            self.save(int(get_step()), get_state(), {"preempted": True},
                      blocking=True)
            raise SystemExit(128 + signum)

        signal.signal(signal.SIGTERM, handler)
        return handler
