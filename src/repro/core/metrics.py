"""Similarity metrics used by registration and its evaluation (paper §6-7)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

__all__ = ["ssd", "mae", "ncc", "ssim"]


def ssd(a, b):
    return jnp.mean((a - b) ** 2)


def mae(a, b):
    """Mean absolute error on normalised intensities (paper Table 5)."""
    return jnp.mean(jnp.abs(_norm(a) - _norm(b)))


def _norm(x):
    lo, hi = jnp.min(x), jnp.max(x)
    return (x - lo) / jnp.maximum(hi - lo, 1e-8)


def ncc(a, b):
    a = a - jnp.mean(a)
    b = b - jnp.mean(b)
    return jnp.sum(a * b) / jnp.maximum(
        jnp.sqrt(jnp.sum(a**2) * jnp.sum(b**2)), 1e-8
    )


def _uniform_filter(x, size):
    w = jnp.ones((size,) * 3, x.dtype) / size**3
    return lax.conv_general_dilated(
        x[None, None], w[None, None], (1, 1, 1), "VALID",
        dimension_numbers=("NCXYZ", "OIXYZ", "NCXYZ"),
    )[0, 0]


def ssim(a, b, *, window=7, k1=0.01, k2=0.03):
    """Structured Similarity Index (3-D, uniform window — paper Table 5)."""
    a, b = _norm(a), _norm(b)
    c1, c2 = k1**2, k2**2
    mu_a = _uniform_filter(a, window)
    mu_b = _uniform_filter(b, window)
    aa = _uniform_filter(a * a, window) - mu_a**2
    bb = _uniform_filter(b * b, window) - mu_b**2
    ab = _uniform_filter(a * b, window) - mu_a * mu_b
    s = ((2 * mu_a * mu_b + c1) * (2 * ab + c2)) / (
        (mu_a**2 + mu_b**2 + c1) * (aa + bb + c2)
    )
    return jnp.mean(s)
