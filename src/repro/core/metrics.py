"""Evaluation metrics for registration quality (paper §6-7, Table 5).

The *loss-form* terms the optimiser minimises live in
``repro.core.similarity`` (the pluggable subsystem behind the
``similarity=`` knob); ``ssd`` and ``ncc`` are re-exported from there for
backwards compatibility.  This module keeps the evaluation-only measures:
``mae`` (Table 5) and ``ssim``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.similarity import (
    _norm01 as _norm,
    ncc,
    ssd,
    uniform_filter as _uniform_filter,
)

__all__ = ["ssd", "mae", "ncc", "ssim"]


def mae(a, b):
    """Mean absolute error on normalised intensities (paper Table 5)."""
    return jnp.mean(jnp.abs(_norm(a) - _norm(b)))


def ssim(a, b, *, window=7, k1=0.01, k2=0.03):
    """Structured Similarity Index (3-D, uniform window — paper Table 5).

    The window clamps to the volume's smallest extent, so sub-window³
    volumes (coarse pyramid levels, tiny test fixtures) stay valid instead
    of crashing the VALID convolution.
    """
    a, b = _norm(a), _norm(b)
    c1, c2 = k1**2, k2**2
    mu_a = _uniform_filter(a, window)
    mu_b = _uniform_filter(b, window)
    aa = _uniform_filter(a * a, window) - mu_a**2
    bb = _uniform_filter(b * b, window) - mu_b**2
    ab = _uniform_filter(a * b, window) - mu_a * mu_b
    s = ((2 * mu_a * mu_b + c1) * (2 * ab + c2)) / (
        (mu_a**2 + mu_b**2 + c1) * (aa + bb + c2)
    )
    return jnp.mean(s)
