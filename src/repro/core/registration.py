"""Non-rigid (FFD) and affine registration — the paper's application layer.

A JAX re-build of the NiftyReg workflow the paper integrates into (§6):
multi-resolution pyramid, SSD similarity, bending-energy regularisation,
gradient-based optimisation of the control grid.  The expensive inner step —
expanding the control grid to the dense deformation field — is exactly the
paper's BSI and is dispatched through ``repro.core.interpolate`` so any of
the algorithm forms / kernels can be plugged in (``mode=``, ``impl=``).

Hand-derived gradients (NiftyReg's approach) are replaced by autodiff; the
BSI forward is still the dominant cost, so the paper's speedup story carries.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ffd, metrics

__all__ = ["RegistrationResult", "affine_register", "ffd_register", "downsample2"]


@dataclasses.dataclass
class RegistrationResult:
    warped: Any              # registered moving volume
    params: Any              # affine matrix or control grid pytree per level
    losses: list             # loss trace
    seconds: float           # wall time
    bsi_seconds: float = 0.0 # time inside BSI (paper Figs. 8-9 breakdown)


def downsample2(vol):
    """2x average-pool downsampling (pyramid level)."""
    X, Y, Z = (s - s % 2 for s in vol.shape)
    v = vol[:X, :Y, :Z].reshape(X // 2, 2, Y // 2, 2, Z // 2, 2)
    return v.mean(axis=(1, 3, 5))


def _adam_update(g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**step)
    vh = v / (1 - b2**step)
    return lr * mh / (jnp.sqrt(vh) + eps), m, v


def affine_register(fixed, moving, *, iters=60, lr=0.02):
    """Optimise a 3x4 affine (around the volume centre) minimising SSD."""
    fixed = jnp.asarray(fixed, jnp.float32)
    moving = jnp.asarray(moving, jnp.float32)
    centre = (jnp.asarray(fixed.shape, jnp.float32) - 1.0) / 2.0
    X, Y, Z = fixed.shape
    ident = jnp.stack(
        jnp.meshgrid(
            jnp.arange(X, dtype=jnp.float32),
            jnp.arange(Y, dtype=jnp.float32),
            jnp.arange(Z, dtype=jnp.float32),
            indexing="ij",
        ),
        axis=-1,
    )

    def loss_fn(theta):
        A = theta[:, :3] + jnp.eye(3)
        t = theta[:, 3]
        coords = (ident - centre) @ A.T + centre + t
        warped = ffd.trilinear_sample(moving, coords)
        return metrics.ssd(warped, fixed)

    @jax.jit
    def step_fn(theta, m, v, i):
        g = jax.grad(loss_fn)(theta)
        upd, m, v = _adam_update(g, m, v, i, lr)
        return theta - upd, m, v

    theta = jnp.zeros((3, 4), jnp.float32)
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    losses = []
    t0 = time.perf_counter()
    for i in range(1, iters + 1):
        theta, m, v = step_fn(theta, m, v, i)
        if i % 10 == 0 or i == iters:
            losses.append(float(loss_fn(theta)))
    A = theta[:, :3] + jnp.eye(3)
    coords = (ident - centre) @ A.T + centre + theta[:, 3]
    warped = ffd.trilinear_sample(moving, coords)
    return RegistrationResult(warped, theta, losses, time.perf_counter() - t0)


def ffd_register(
    fixed,
    moving,
    *,
    tile=(5, 5, 5),
    levels=2,
    iters=40,
    lr=0.5,
    bending_weight=5e-3,
    mode="separable",
    impl="jnp",
    measure_bsi_time=False,
):
    """Multi-resolution FFD registration (NiftyReg workflow, paper §6).

    Pyramid: coarse-to-fine on 2x-downsampled volumes; the control grid is
    upsampled (re-expanded through BSI itself) between levels.
    """
    fixed = jnp.asarray(fixed, jnp.float32)
    moving = jnp.asarray(moving, jnp.float32)
    tile = tuple(int(t) for t in tile)

    pyramid = [(fixed, moving)]
    for _ in range(levels - 1):
        f, m = pyramid[-1]
        pyramid.append((downsample2(f), downsample2(m)))
    pyramid = pyramid[::-1]  # coarse -> fine

    bsi_fn = functools.partial(ffd.dense_field, mode=mode, impl=impl)
    phi = None
    losses = []
    bsi_seconds = 0.0
    t0 = time.perf_counter()

    for level, (f, m) in enumerate(pyramid):
        gshape = ffd.grid_shape_for_volume(f.shape, tile)
        if phi is None:
            phi = jnp.zeros(gshape + (3,), jnp.float32)
        else:
            phi = _upsample_grid(phi, gshape)

        def loss_fn(p, f=f, m=m):
            disp = bsi_fn(p, tile, f.shape)
            warped = ffd.warp_volume(m, disp)
            return metrics.ssd(warped, f) + bending_weight * ffd.bending_energy(p)

        @jax.jit
        def step_fn(p, mm, vv, i, f=f, m=m):
            g = jax.grad(loss_fn)(p)
            upd, mm, vv = _adam_update(g, mm, vv, i, lr)
            return p - upd, mm, vv

        mm = jnp.zeros_like(phi)
        vv = jnp.zeros_like(phi)
        for i in range(1, iters + 1):
            phi, mm, vv = step_fn(phi, mm, vv, i)
        phi.block_until_ready()
        losses.append(float(loss_fn(phi)))

        if measure_bsi_time and level == len(pyramid) - 1:
            # Isolate the BSI fraction the paper optimises (Figs. 8-9).
            dense = jax.jit(lambda p: bsi_fn(p, tile, f.shape))
            dense(phi).block_until_ready()  # compile
            reps = 3
            t1 = time.perf_counter()
            for _ in range(reps):
                dense(phi).block_until_ready()
            # 2 BSI evaluations per optimisation step (forward + grad).
            bsi_seconds = (time.perf_counter() - t1) / reps * iters * 2

    disp = bsi_fn(phi, tile, fixed.shape)
    warped = ffd.warp_volume(moving, disp)
    return RegistrationResult(
        warped, phi, losses, time.perf_counter() - t0, bsi_seconds
    )


def _upsample_grid(phi, new_shape):
    """Upsample a control grid to a finer level's grid shape (trilinear)."""
    old = phi.shape[:3]
    coords = jnp.stack(
        jnp.meshgrid(
            *[jnp.linspace(0.0, o - 1.0, n) for o, n in zip(old, new_shape)],
            indexing="ij",
        ),
        axis=-1,
    )
    flat = ffd.trilinear_sample(
        phi[..., 0], coords
    )  # sample each component separately
    comps = [ffd.trilinear_sample(phi[..., c], coords) for c in range(phi.shape[-1])]
    del flat
    return jnp.stack(comps, axis=-1) * 2.0  # displacements double at 2x res
