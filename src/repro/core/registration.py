"""Non-rigid (FFD) and affine registration — the paper's application layer.

A JAX re-build of the NiftyReg workflow the paper integrates into (§6):
multi-resolution pyramid, a pluggable similarity term (SSD by default; NCC,
local NCC and differentiable NMI for multi-modal pairs — see
``repro.core.similarity``), bending-energy regularisation, gradient-based
optimisation of the control grid.  The expensive inner step —
expanding the control grid to the dense deformation field — is exactly the
paper's BSI and is dispatched through ``repro.core.interpolate`` so any of
the algorithm forms / kernels can be plugged in (``mode=``, ``impl=``;
both default to ``"auto"``, the ``repro.engine.autotune`` winner).

The inner optimisation is device-resident: each pyramid level runs as ONE
``jax.lax.scan``-compiled program (``repro.engine.loop``) under the
pluggable ``optimizer=`` registry (``repro.engine.optimizer`` — Adam by
default, L-BFGS / Gauss-Newton for second-order convergence), with runners
cached per configuration so repeated calls pay zero re-jits, and the
params buffer donated on accelerator backends.  For many pairs at
once, use ``repro.engine.register_batch`` — the same pipeline under ``vmap``.

Hand-derived gradients (NiftyReg's approach) are replaced by autodiff; the
BSI forward is still the dominant cost, so the paper's speedup story carries.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import ffd
from repro.core.ffd import downsample2  # re-exported (seed API)
from repro.core.options import (UNSET, RegistrationOptions,
                                merge_legacy_options)
from repro.engine.autotune import resolve_options
from repro.engine.batch import ffd_level_objective
from repro.engine.loop import make_adam_runner

__all__ = ["RegistrationResult", "affine_register", "ffd_register", "downsample2"]

# affine_register's historical keyword defaults (the FFD defaults live on
# RegistrationOptions itself)
AFFINE_DEFAULTS = RegistrationOptions(iters=60, lr=0.02)


@dataclasses.dataclass
class RegistrationResult:
    warped: Any              # registered moving volume
    params: Any              # affine matrix or control grid pytree per level
    losses: list             # loss trace
    seconds: float           # wall time
    bsi_seconds: float = 0.0 # time inside BSI (paper Figs. 8-9 breakdown)
    steps: Any = None        # optimiser steps per level when stop= was set


def _affine_ident_centre(vol_shape):
    centre = (jnp.asarray(vol_shape, jnp.float32) - 1.0) / 2.0
    X, Y, Z = vol_shape
    ident = jnp.stack(
        jnp.meshgrid(
            jnp.arange(X, dtype=jnp.float32),
            jnp.arange(Y, dtype=jnp.float32),
            jnp.arange(Z, dtype=jnp.float32),
            indexing="ij",
        ),
        axis=-1,
    )
    return ident, centre


def _affine_warp(theta, moving, vol_shape):
    ident, centre = _affine_ident_centre(vol_shape)
    A = theta[:, :3] + jnp.eye(3)
    coords = (ident - centre) @ A.T + centre + theta[:, 3]
    return ffd.trilinear_sample(moving, coords)


@functools.lru_cache(maxsize=32)
def _affine_runner(vol_shape, options):
    """One compiled affine loop per (shape, options) — ``options`` is a
    canonical ``RegistrationOptions.for_affine()`` instance (which keeps the
    ``optimizer`` axis), the sole cache key beyond the volume shape."""
    from repro.core.similarity import resolve_similarity
    from repro.engine.optimizer import make_objective

    sim_key, sim = resolve_similarity(options.similarity)

    def loss_builder(f, mov):
        def loss_fn(theta):
            return sim(_affine_warp(theta, mov, vol_shape), f)

        if sim_key != "ssd":
            return loss_fn

        # ssd exposes its least-squares residual (mean(r**2), no
        # regulariser on the affine model) so optimizer="gauss_newton"
        # can linearise the warp directly
        def residual_fn(theta):
            return (_affine_warp(theta, mov, vol_shape) - f).ravel()

        return make_objective(loss_fn, residual_fn=residual_fn)

    return make_adam_runner(loss_builder, options=options)


def affine_register(fixed, moving, *, options=None, iters=UNSET, lr=UNSET,
                    similarity=UNSET, stop=UNSET, optimizer=UNSET):
    """Optimise a 3x4 affine (around the volume centre) on ``similarity``.

    The whole optimisation is one scan-compiled program; the runner is
    cached by (shape, options), so repeat calls skip compilation.  Configure
    via ``options=RegistrationOptions(...)`` — only its ``iters`` / ``lr`` /
    ``similarity`` / ``stop`` fields apply to the affine model (legacy
    defaults: ``iters=60, lr=0.02``); the legacy keywords still work through
    the deprecation shim and produce bit-identical results.  ``similarity``
    is a registered name (``"ssd" | "ncc" | "lncc" | "nmi"``) or a loss
    callable (lower = better).  ``stop`` (a ``ConvergenceConfig``) runs the
    loop as an early-stopped ``lax.while_loop`` instead — the result's
    ``steps`` records the optimiser steps actually taken (``stop.max_iters``
    defaults to ``iters``).  ``optimizer`` (``"adam" | "lbfgs" |
    "gauss_newton"`` or an ``engine.optimizer`` spec) picks the loop —
    ``"gauss_newton"`` needs ``similarity="ssd"`` and linearises the affine
    warp directly.
    """
    fixed = jnp.asarray(fixed, jnp.float32)
    moving = jnp.asarray(moving, jnp.float32)
    opts = merge_legacy_options(
        "affine_register", options,
        dict(iters=iters, lr=lr, similarity=similarity, stop=stop,
             optimizer=optimizer),
        defaults=AFFINE_DEFAULTS).for_affine()
    stop = opts.stop  # resolved by for_affine()'s normalized()
    t0 = time.perf_counter()
    runner = _affine_runner(fixed.shape, opts)
    theta0 = jnp.zeros((3, 4), jnp.float32)
    out = runner(theta0, fixed, moving)
    theta, trace = out[:2]
    steps = [int(out[2])] if stop is not None else None
    # same sampling points as the seed's Python loop: every 10th + last
    # (the early-stopped trace is padded with its final loss past the stop)
    span = opts.iters if stop is None else stop.max_iters
    marks = sorted(set(range(10, span + 1, 10)) | {span})
    losses = [float(trace[i - 1]) for i in marks]
    warped = _affine_warp(theta, moving, fixed.shape)
    jax.block_until_ready(warped)
    return RegistrationResult(warped, theta, losses,
                              time.perf_counter() - t0, steps=steps)


@functools.lru_cache(maxsize=64)  # bounded: ~levels x configs in flight
def _ffd_level_runner(vol_shape, options):
    """One compiled level loop per (shape, options) — the resolved
    ``RegistrationOptions`` instance is the sole cache key beyond shape."""
    del vol_shape  # cache key only; shapes re-trace via jit

    def loss_builder(f, mov):
        return ffd_level_objective(f, mov, tile=options.tile,
                                   bending_weight=options.bending_weight,
                                   mode=options.mode, impl=options.impl,
                                   grad_impl=options.grad_impl,
                                   compute_dtype=options.compute_dtype,
                                   similarity=options.similarity,
                                   transform=options.transform,
                                   regularizer=options.regularizer,
                                   fused=options.fused)

    return make_adam_runner(loss_builder, options=options)


def ffd_register(
    fixed,
    moving,
    *,
    options=None,
    tile=UNSET,
    levels=UNSET,
    iters=UNSET,
    lr=UNSET,
    bending_weight=UNSET,
    mode=UNSET,
    impl=UNSET,
    grad_impl=UNSET,
    compute_dtype=UNSET,
    similarity=UNSET,
    transform=UNSET,
    regularizer=UNSET,
    stop=UNSET,
    optimizer=UNSET,
    measure_bsi_time=False,
):
    """Multi-resolution FFD registration (NiftyReg workflow, paper §6).

    Pyramid: coarse-to-fine on 2x-downsampled volumes; the control grid is
    upsampled (re-expanded through BSI itself) between levels.  Each level's
    optimiser loop is a single ``lax.scan`` program — one compile per pyramid
    level, cached across calls, keyed by the resolved
    ``RegistrationOptions``.  Configure via ``options=`` (a
    ``repro.core.RegistrationOptions``); the legacy keyword arguments still
    work through a deprecation shim and produce bit-identical results.
    ``mode``/``impl``/``grad_impl`` default to ``"auto"``: the autotuned
    fastest BSI forward x adjoint pair for the finest-level grid under the
    chosen ``similarity``'s forward+backward workload (``grad_impl`` selects
    between XLA autodiff and the analytic gather-only custom VJP — see
    ``repro.core.interpolate``).  ``compute_dtype`` (e.g. ``"bfloat16"``)
    runs BSI + warp in reduced precision with fp32 params and adjoint
    accumulation.  ``similarity`` is a registered name (``"ssd" | "ncc" |
    "lncc" | "nmi"`` — NMI being the multi-modal NiftyReg path) or a
    ``(warped, fixed) -> scalar`` loss callable (lower = better; see
    ``repro.core.similarity``).  ``transform`` (``"displacement" |
    "velocity"`` or a ``repro.core.transform`` spec) picks the deformation
    model — ``"velocity"`` integrates a stationary velocity field by scaling
    and squaring, giving invertible, fold-free (diffeomorphic) warps;
    ``regularizer`` (``"none" | "bending"`` or a ``repro.core.regularizer``
    spec) picks the smoothness term — ``"bending"`` is the analytic
    B-spline bending energy with closed-form gradient, replacing the legacy
    ``bending_weight`` finite-difference proxy.  ``stop`` (a
    ``ConvergenceConfig``, see
    ``repro.engine.convergence``) replaces each level's fixed-``iters`` scan
    with an early-stopped ``lax.while_loop`` (``stop.max_iters`` defaults to
    ``iters``); the result's ``steps`` then lists the optimiser steps each
    level actually ran.  ``optimizer`` (``"adam" | "lbfgs" | "gauss_newton"``
    or an ``engine.optimizer`` spec, see the README's Optimisers table)
    picks each level's optimisation loop — the default ``"adam"`` is
    bit-identical to the historical engine; the second-order entries
    typically converge hard pairs in a fraction of the steps
    (``"gauss_newton"`` requires ``similarity="ssd"``).
    """
    fixed = jnp.asarray(fixed, jnp.float32)
    moving = jnp.asarray(moving, jnp.float32)
    opts = merge_legacy_options(
        "ffd_register", options,
        dict(tile=tile, levels=levels, iters=iters, lr=lr,
             bending_weight=bending_weight, mode=mode, impl=impl,
             grad_impl=grad_impl, compute_dtype=compute_dtype,
             similarity=similarity, transform=transform,
             regularizer=regularizer, stop=stop, optimizer=optimizer))
    opts = resolve_options(opts, fixed.shape)  # autotune + canonicalise
    tile, stop = opts.tile, opts.stop

    pyramid = [(fixed, moving)]
    for _ in range(opts.levels - 1):
        f, m = pyramid[-1]
        pyramid.append((downsample2(f), downsample2(m)))
    pyramid = pyramid[::-1]  # coarse -> fine

    bsi_fn = functools.partial(ffd.dense_field, mode=opts.mode,
                               impl=opts.impl)
    phi = None
    losses = []
    steps = [] if stop is not None else None
    bsi_seconds = 0.0
    t0 = time.perf_counter()

    for level, (f, m) in enumerate(pyramid):
        gshape = ffd.grid_shape_for_volume(f.shape, tile)
        if phi is None:
            phi = jnp.zeros(gshape + (3,), jnp.float32)
        else:
            phi = ffd.upsample_grid(phi, gshape)

        runner = _ffd_level_runner(f.shape, opts)
        out = runner(phi, f, m)
        phi, trace = out[:2]
        if stop is not None:
            steps.append(int(out[2]))
        phi.block_until_ready()
        losses.append(float(trace[-1]))

        if measure_bsi_time and level == len(pyramid) - 1:
            # Isolate the BSI fraction the paper optimises (Figs. 8-9).
            dense = jax.jit(lambda p: bsi_fn(p, tile, f.shape))
            dense(phi).block_until_ready()  # compile
            reps = 3
            t1 = time.perf_counter()
            for _ in range(reps):
                dense(phi).block_until_ready()
            # 2 BSI evaluations per optimisation step (forward + grad),
            # scaled by the steps this level actually ran.
            ran = steps[-1] if stop is not None else opts.iters
            bsi_seconds = (time.perf_counter() - t1) / reps * ran * 2

    from repro.core.transform import dense_displacement

    disp = dense_displacement(opts.transform, phi, tile, fixed.shape,
                              mode=opts.mode, impl=opts.impl)
    warped = ffd.warp_volume(moving, disp)
    return RegistrationResult(
        warped, phi, losses, time.perf_counter() - t0, bsi_seconds,
        steps=steps
    )
