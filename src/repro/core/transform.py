"""Pluggable transform models: how a control grid becomes a deformation.

The registration stack so far hardcoded one transform — the classic FFD
(Rueckert et al.): the control grid *is* a displacement field, BSI expands
it densely, done.  FFD is fast but physically unconstrained: nothing stops
the optimiser from folding space (negative Jacobian determinant), which is
disqualifying for the paper's IGS target — an intra-operative liver overlay
that folds tissue through itself is worse than no overlay.

This module makes the transform a layer (the same registry shape as
``similarity=`` — see ``core.registry``), with two built-ins:

``displacement``
    Today's FFD, unchanged: ``dense_displacement`` is exactly
    ``ffd.dense_field`` cropped to the volume — the default, bit-identical
    to the pre-transform-axis pipeline.

``velocity``
    A **stationary velocity field** (Arsigny et al.; Brunn et al.'s "Fast
    GPU 3D Diffeomorphic Image Registration" is the GPU treatment — see
    PAPERS.md): the control grid parameterises a velocity ``v``, and the
    displacement is the time-1 flow ``exp(v) - id``, computed by **scaling
    and squaring** — ``u_0 = v / 2^K`` then ``K`` self-compositions
    ``u_{k+1} = u_k ∘ (id + u_k) + u_k``.  The flow of a smooth field is a
    diffeomorphism: invertible (integrate ``-v`` for the inverse) and
    fold-free (Jacobian determinant > 0 everywhere) by construction.  Each
    squaring step is a dense-field composition through the same clamped
    trilinear evaluation the warp uses, and the BSI expansion underneath
    still dispatches through the autotuned kernel stack — scaling and
    squaring multiplies evaluation count, which is precisely the workload
    the autotuned forms and the analytic adjoint are for.

Specs are small frozen dataclasses, so a resolved transform drops straight
into ``RegistrationOptions`` as a hashable program-cache-key field; the
factory spelling (``velocity(squarings=4)``) builds parameter variants.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ffd
from repro.core.registry import Registry

__all__ = [
    "TRANSFORMS",
    "DisplacementTransform",
    "VelocityTransform",
    "available_transforms",
    "compose_displacement",
    "dense_displacement",
    "displacement",
    "jacobian_determinant",
    "resolve_transform",
    "scaling_and_squaring",
    "transform_token",
    "velocity",
]


@dataclasses.dataclass(frozen=True)
class DisplacementTransform:
    """Classic FFD: the control grid is the displacement field (default)."""

    name = "displacement"


@dataclasses.dataclass(frozen=True)
class VelocityTransform:
    """Stationary velocity field integrated by scaling and squaring.

    ``squarings`` is the number of self-composition steps ``K``: the field
    is scaled by ``2^-K`` and composed with itself ``K`` times.  More steps
    tighten the small-deformation assumption each composition rests on
    (NiftyReg's velocity mode uses 6); fewer save dense-field compositions.
    """

    name = "velocity"
    squarings: int = 6

    def __post_init__(self):
        k = int(self.squarings)
        if not 1 <= k <= 12:
            raise ValueError(
                f"velocity squarings must be in [1, 12], got {self.squarings!r}")
        object.__setattr__(self, "squarings", k)


TRANSFORMS = Registry(
    "transform",
    passthrough=lambda o: isinstance(o, (DisplacementTransform,
                                         VelocityTransform)))


def displacement() -> DisplacementTransform:
    """The classic-FFD transform spec (the default)."""
    return DisplacementTransform()


def velocity(squarings=6) -> VelocityTransform:
    """A stationary-velocity-field transform spec (diffeomorphic)."""
    return VelocityTransform(squarings=squarings)


TRANSFORMS.register("displacement", DisplacementTransform())
TRANSFORMS.register("velocity", VelocityTransform())


def available_transforms():
    """Sorted names of the registered transform models."""
    return TRANSFORMS.names()


def resolve_transform(transform):
    """Resolve a name-or-spec to a frozen transform spec instance.

    Accepts a registered name (``"displacement"`` | ``"velocity"``) or a
    spec dataclass (``DisplacementTransform()`` / ``VelocityTransform(...)``
    — factory variants included); anything else raises with the valid names.
    """
    _, spec = TRANSFORMS.resolve(transform)
    return spec


def transform_token(transform) -> str:
    """A short string naming the transform for disk-cache keys and logs."""
    spec = resolve_transform(transform)
    if isinstance(spec, VelocityTransform):
        return f"velocity(squarings={spec.squarings})"
    return "displacement"


def compose_displacement(u, v):
    """The displacement of the composed map ``(id + u) ∘ (id + v)``.

    ``w(x) = v(x) + u(x + v(x))`` — each channel of ``u`` is sampled at the
    ``v``-displaced coordinates with the same clamped trilinear evaluation
    ``ffd.warp_volume`` uses (clamping keeps the composition smooth for
    autodiff; a flow that leaves the volume saturates at the border rather
    than extrapolating).  Fields are ``(X, Y, Z, 3)`` in voxel units.
    """
    coord_dtype = jnp.promote_types(v.dtype, jnp.float32)
    u = jnp.asarray(u, coord_dtype)
    v = jnp.asarray(v, coord_dtype)
    X, Y, Z = v.shape[:3]
    ident = jnp.stack(
        jnp.meshgrid(jnp.arange(X, dtype=coord_dtype),
                     jnp.arange(Y, dtype=coord_dtype),
                     jnp.arange(Z, dtype=coord_dtype),
                     indexing="ij"),
        axis=-1)
    coords = ident + v
    sampled = jax.vmap(ffd.trilinear_sample, in_axes=(3, None), out_axes=3)(
        u, coords)
    return v + sampled


def scaling_and_squaring(vel, squarings):
    """Integrate a stationary velocity field to its time-1 displacement.

    ``u = exp(vel) - id`` via ``squarings`` doublings: start from
    ``vel / 2^K`` (small enough that one Euler step approximates the flow)
    and square ``K`` times — ``u <- u ∘ (id + u) + u`` — each doubling the
    integration time.  ``2^K`` compositions of accuracy for ``K`` dense
    evaluations.
    """
    k = int(squarings)
    u = jnp.asarray(vel, jnp.promote_types(vel.dtype, jnp.float32))
    u = u / (2.0 ** k)
    for _ in range(k):
        u = compose_displacement(u, u)
    return u


def dense_displacement(transform, phi, tile, vol_shape, *, mode="separable",
                       impl="jnp", grad_impl="xla", compute_dtype=None,
                       inverse=False):
    """Control grid -> dense displacement field under ``transform``.

    The transform-generic face of ``ffd.dense_field``: ``displacement``
    returns the BSI expansion itself (bit-identical to the pre-transform
    pipeline); ``velocity`` expands the grid to a velocity field and
    integrates it by scaling and squaring.  ``mode`` / ``impl`` /
    ``grad_impl`` / ``compute_dtype`` configure the BSI expansion exactly as
    in ``dense_field`` (the compositions themselves run in fp32 coordinate
    precision, like the warp).

    ``inverse=True`` returns the displacement of the *inverse* map — for
    ``velocity`` that is the flow of ``-v`` (the group inverse, exact up to
    integration error), which is what makes the model invertible by
    construction; ``displacement`` has no closed-form inverse and raises.
    """
    spec = resolve_transform(transform)
    if isinstance(spec, DisplacementTransform):
        if inverse:
            raise ValueError(
                "the displacement (classic FFD) transform has no analytic "
                "inverse; use transform='velocity' for invertible fields")
        return ffd.dense_field(phi, tile, vol_shape, mode=mode, impl=impl,
                               grad_impl=grad_impl,
                               compute_dtype=compute_dtype)
    vel = ffd.dense_field(phi, tile, vol_shape, mode=mode, impl=impl,
                          grad_impl=grad_impl, compute_dtype=compute_dtype)
    if inverse:
        vel = -vel
    return scaling_and_squaring(vel, spec.squarings)


def jacobian_determinant(disp):
    """Per-voxel Jacobian determinant of the map ``id + disp``.

    Central differences in the interior, one-sided at the borders (the
    ``jnp.gradient`` stencil).  ``det > 0`` everywhere means the map
    preserves orientation — no folding; the minimum over the volume is the
    standard fold diagnostic reported by the IGS benchmarks and tests.
    """
    disp = jnp.asarray(disp, jnp.float32)
    rows = []
    for c in range(3):
        grads = jnp.gradient(disp[..., c], axis=(0, 1, 2))
        rows.append(jnp.stack(
            [g + (1.0 if a == c else 0.0) for a, g in enumerate(grads)],
            axis=-1))
    jac = jnp.stack(rows, axis=-2)  # (X, Y, Z, 3, 3): d(x+u)_c / d x_a
    return jnp.linalg.det(jac)
