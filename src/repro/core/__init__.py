# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core.options import UNSET, RegistrationOptions, merge_legacy_options
from repro.core.registry import Registry

__all__ = ["UNSET", "Registry", "RegistrationOptions", "merge_legacy_options"]
