"""Pluggable similarity subsystem — the loss-form terms registration optimises.

The paper's application layer (§6) is NiftyReg's FFD workflow, whose
multi-modal cases (CT↔CBCT liver) run on NMI rather than SSD; Budelmann et
al. (PAPERS.md) likewise swap the distance measure (NGF) under an unchanged
GPU optimisation loop.  This module makes the measure a layer, not a
constant: a registry of *loss-form* similarity terms, each a scan-safe,
``vmap``-able ``(warped, fixed) -> scalar`` with a uniform sign convention
(**lower = better**), consumed by ``engine.batch.ffd_level_loss`` and
everything above it via a ``similarity=`` knob (name or callable).

Registered terms
----------------
``ssd``   mean squared intensity difference — mono-modal default.
``ncc``   ``1 - (global normalised cross-correlation)`` — linear intensity
          relationships.
``lncc``  windowed local NCC (``1 - mean local cc²``) — spatially varying
          intensity relationships; window clamps to the volume's smallest
          extent so coarse pyramid levels (< window³) stay valid.
``nmi``   ``2 - NMI`` from a Parzen-window (Gaussian soft-binned) joint
          histogram — fully differentiable, the NiftyReg multi-modal path.
          ``nmi(bins=...)`` builds variants with a different soft-bin count.

Custom terms: pass any callable with the same contract as ``similarity=``,
or add it to the registry with :func:`register_similarity`.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
from jax import lax

from repro.core.registry import Registry

__all__ = [
    "SIMILARITIES",
    "available_similarities",
    "fused_spec",
    "lncc",
    "ncc",
    "ncc_loss",
    "nmi",
    "register_similarity",
    "resolve_similarity",
    "similarity_token",
    "ssd",
    "uniform_filter",
]

# The registry instance behind the public helpers below — the same shared
# ``core.registry.Registry`` shape as ``transform=`` and ``regularizer=``.
# Custom loss callables pass through unregistered (they are their own key).
SIMILARITIES = Registry("similarity", passthrough=callable,
                        hint="or pass a callable")

# Pre-Registry this module kept its entries in a module-level ``_REGISTRY``
# dict; keep that name bound to the live entry table so existing code (and
# tests) that mutate it directly keep working.
_REGISTRY = SIMILARITIES._entries


def register_similarity(name, fn=None):
    """Register ``fn`` as similarity ``name`` (also usable as a decorator).

    ``fn`` must be a scan-safe, ``vmap``-able ``(warped, fixed) -> scalar``
    loss (lower = better) built from traceable jnp ops.
    """
    return SIMILARITIES.register(name, fn)


def available_similarities():
    """Sorted names of the registered similarity terms."""
    return SIMILARITIES.names()


def resolve_similarity(similarity):
    """Resolve a name-or-callable to ``(key, loss_fn)``.

    ``key`` is hashable and stable across calls (the registry name, or the
    callable itself), so callers can use it in compiled-runner cache keys.
    A callable that is itself registered canonicalises to its registry name,
    so ``similarity="nmi"`` and ``similarity=nmi()`` share one cache key
    (and one autotune entry) instead of duplicating compiles and sweeps.
    """
    return SIMILARITIES.resolve(similarity)


def fused_spec(similarity):
    """The fused-kernel spec tuple for ``similarity``, or ``None``.

    Every built-in loss (and every ``lncc()`` / ``nmi()`` factory variant)
    carries a ``_fused_spec`` attribute naming its kind and parameters —
    e.g. ``("lncc", window, eps)`` — which is all
    ``kernels.ops.fused_similarity_loss`` needs to reproduce the loss as
    in-VMEM partial sums.  Custom callables without the attribute return
    ``None``: they have no fused accumulator and must run unfused.
    """
    _, fn = resolve_similarity(similarity)
    return getattr(fn, "_fused_spec", None)


def _loss_from_spec(spec):
    """Rebuild the registry loss a fused spec tuple describes.

    The exact inverse of :func:`fused_spec` — the factories are lru-cached,
    so this returns the *same* callable object the spec came from and the
    fused custom VJP's recompute-backward differentiates the identical loss.
    """
    kind = spec[0]
    if kind == "ssd":
        return ssd
    if kind == "ncc":
        return ncc_loss
    if kind == "lncc":
        return lncc(spec[1], spec[2])
    if kind == "nmi":
        return nmi(spec[1], spec[2], spec[3])
    raise ValueError(f"unknown fused similarity spec {spec!r}")


def similarity_token(similarity) -> str:
    """A short string naming ``similarity`` for disk-cache keys and logs.

    Registry names and the built-in factories are fully self-describing
    (factory tokens embed every parameter).  Custom callables fall back to
    ``__qualname__`` — give distinct custom losses distinct qualnames or
    their autotune cache entries will collide.
    """
    if callable(similarity):
        return getattr(similarity, "__qualname__", repr(similarity))
    return str(similarity)


# --- shared pieces -----------------------------------------------------------


def _norm01(x):
    lo, hi = jnp.min(x), jnp.max(x)
    return (x - lo) / jnp.maximum(hi - lo, 1e-8)


def uniform_filter(x, size):
    """3-D VALID box filter; ``size`` clamps to the smallest volume extent."""
    size = max(1, min(int(size), *(int(s) for s in x.shape)))
    w = jnp.ones((size,) * 3, x.dtype) / size**3
    return lax.conv_general_dilated(
        x[None, None],
        w[None, None],
        (1, 1, 1),
        "VALID",
        dimension_numbers=("NCXYZ", "OIXYZ", "NCXYZ"),
    )[0, 0]


# --- loss-form terms ---------------------------------------------------------


@register_similarity("ssd")
def ssd(warped, fixed):
    """Mean squared intensity difference (mono-modal default)."""
    return jnp.mean((warped - fixed) ** 2)


ssd._fused_spec = ("ssd",)


def ncc(a, b):
    """Global normalised cross-correlation coefficient (in ``[-1, 1]``)."""
    a = a - jnp.mean(a)
    b = b - jnp.mean(b)
    return jnp.sum(a * b) / jnp.maximum(jnp.sqrt(jnp.sum(a**2) * jnp.sum(b**2)), 1e-8)


@register_similarity("ncc")
def ncc_loss(warped, fixed):
    """``1 - NCC``: zero at perfect linear correlation."""
    return 1.0 - ncc(warped, fixed)


ncc_loss._fused_spec = ("ncc",)


@functools.lru_cache(maxsize=None)
def lncc(window=9, eps=1e-5):
    """Build a windowed local-NCC loss: ``1 - mean(local cc²)``.

    The factory is cached so equal-parameter calls return the same callable
    (and therefore hit the same compiled-runner caches downstream).
    """
    window, eps = int(window), float(eps)

    def lncc_loss(warped, fixed):
        mu_w = uniform_filter(warped, window)
        mu_f = uniform_filter(fixed, window)
        var_w = uniform_filter(warped * warped, window) - mu_w**2
        var_f = uniform_filter(fixed * fixed, window) - mu_f**2
        cross = uniform_filter(warped * fixed, window) - mu_w * mu_f
        cc = cross**2 / (var_w * var_f + eps)
        return 1.0 - jnp.mean(cc)

    lncc_loss.__qualname__ = f"lncc(window={window},eps={eps:g})"
    lncc_loss._fused_spec = ("lncc", window, eps)
    return lncc_loss


@functools.lru_cache(maxsize=None)
def nmi(bins=32, sigma_ratio=0.5, eps=1e-8):
    """Build a differentiable NMI loss (Parzen soft-binned joint histogram).

    Intensities are min-max normalised to ``[0, 1]`` and scattered onto
    ``bins`` centres with Gaussian Parzen windows of width ``sigma_ratio``
    bin-widths (NiftyReg uses a cubic-spline window; a Gaussian keeps the
    same smoothing with simpler traced code).  The joint histogram is a
    single ``(bins, bins)`` matmul over voxels, so the loss nests under
    ``lax.scan`` / ``vmap`` / ``jit`` unchanged.  Returns ``2 - NMI`` where
    ``NMI = (H(a) + H(b)) / H(a, b)`` ∈ ``[1, 2]`` — lower = better.
    """
    bins, sigma_ratio, eps = int(bins), float(sigma_ratio), float(eps)
    if bins < 2:
        raise ValueError(f"nmi needs >= 2 bins, got {bins}")

    def nmi_loss(warped, fixed):
        a = _norm01(warped).reshape(-1)
        b = _norm01(fixed).reshape(-1)
        centres = jnp.linspace(0.0, 1.0, bins, dtype=a.dtype)
        sigma = sigma_ratio / (bins - 1)
        wa = jnp.exp(-0.5 * ((a[:, None] - centres[None, :]) / sigma) ** 2)
        wb = jnp.exp(-0.5 * ((b[:, None] - centres[None, :]) / sigma) ** 2)
        wa = wa / (jnp.sum(wa, axis=1, keepdims=True) + eps)
        wb = wb / (jnp.sum(wb, axis=1, keepdims=True) + eps)
        pab = wa.T @ wb / a.shape[0]
        pa = jnp.sum(pab, axis=1)
        pb = jnp.sum(pab, axis=0)
        ha = -jnp.sum(pa * jnp.log(pa + eps))
        hb = -jnp.sum(pb * jnp.log(pb + eps))
        hab = -jnp.sum(pab * jnp.log(pab + eps))
        return 2.0 - (ha + hb) / (hab + eps)

    nmi_loss.__qualname__ = (
        f"nmi(bins={bins},sigma_ratio={sigma_ratio:g},eps={eps:g})"
    )
    nmi_loss._fused_spec = ("nmi", bins, sigma_ratio, eps)
    return nmi_loss


register_similarity("lncc", lncc())
register_similarity("nmi", nmi())
