"""B-spline interpolation — public API and the jnp-level algorithm forms.

Three algorithmic forms of paper Eq. (1), mirroring the paper's comparison
matrix (§5), plus a mode dispatcher.  Each form exists twice in the repo:

* here as a pure-jnp implementation — these are the *CPU analogs* (the paper's
  Fig. 7 VT/VV role) and the reference semantics;
* in ``repro.kernels`` as a Pallas TPU kernel with explicit VMEM tiling
  (``bsi_tt``, ``bsi_ttli``, ``bsi_separable``) — the paper's GPU kernels,
  adapted to TPU (DESIGN.md §2).

Forms
-----
``gather``      thread-per-voxel analog (NiftyReg-TV baseline): every voxel
                gathers its 64 control points and weight-sums them.  Maximal
                redundant data movement — the paper's comparison baseline.
``tt``          thread-per-tile: tile-shared slices of the control grid are
                broadcast over the tile's voxels; 64 FMA accumulation steps.
``ttli``        tt + the trilinear/lerp reformulation (126 ops/voxel vs 255).
``separable``   beyond-paper tensor-contraction form: the per-tile sum is a
                Tucker contraction -> three small matmuls (MXU-friendly),
                ~(4/d + 4/d^2 + 4/d^3) MACs/voxel instead of 64.
``matmul``      Wu & Zou's matrix form: the per-axis ``(d, 4)`` LUTs are
                Kronecker-multiplied once per (tile, dtype) into a
                ``(d^3, 64)`` basis matrix and every tile is one dense
                ``(d^3, 64) @ (64, C)`` product — a single MXU/TensorCore-
                shaped contraction with fp32 accumulation over bf16-friendly
                operands, instead of gathers and elementwise FMAs.

Gradient path
-------------
Every form computes the same *linear* function of the control grid, so they
share one analytic adjoint: the Tucker contraction run in reverse
(``bsi_adjoint_separable``, plus a Pallas kernel in
``repro.kernels.bsi_adjoint``).  ``interpolate(..., grad_impl=)`` selects it:

``xla``     plain autodiff of the chosen forward (the historical behaviour;
            transposes the gather form into a per-voxel scatter-add — the
            maximal-data-movement pattern the paper's §3 design avoids).
``jnp``     ``jax.custom_vjp`` whose backward is the separable-transpose:
            each control point's cotangent is a weighted reduction over its
            own (4·tile)^3 support window — gather-only, three small matmuls.
``pallas``  the same contraction as a VMEM-tiled TPU kernel
            (``repro.kernels.bsi_adjoint``), thread-per-*control-point*.
``matmul``  the transposed matrix form as a VMEM-tiled TPU kernel: one
            ``(64, d^3) @ (d^3, tiles*C)`` MXU contraction per control block
            followed by the 64-band shifted overlap-add (also in
            ``repro.kernels.bsi_adjoint``).

Because BSI is linear, the custom VJP stores **no residuals** — the backward
needs only the cotangent, unlike XLA's transpose which re-materialises
whatever intermediates the forward fused.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bspline import basis_matrix, lerp_luts, weight_lut

__all__ = ["bsi_gather", "bsi_tt", "bsi_ttli", "bsi_separable", "bsi_matmul",
           "bsi_adjoint_separable", "bsi_adjoint_matmul", "bsi_adjoint",
           "interpolate", "MODES", "MODE_NAMES", "GRAD_IMPLS"]


def _dims(phi, tile):
    dx, dy, dz = (int(t) for t in tile)
    tx, ty, tz = (int(n) - 3 for n in phi.shape[:3])
    if min(tx, ty, tz) < 1:
        raise ValueError(f"control grid {phi.shape} too small for any tile")
    return (dx, dy, dz), (tx, ty, tz), phi.shape[3]


def bsi_gather(phi, tile, dtype=None):
    """Thread-per-voxel analog: per-voxel 64-point gather + weighted sum."""
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), _ = _dims(phi, tile)
    wx, wy, wz = (weight_lut(d, dtype) for d in (dx, dy, dz))

    x = jnp.arange(tx * dx)
    y = jnp.arange(ty * dy)
    z = jnp.arange(tz * dz)
    bx, ax = x // dx, x % dx
    by, ay = y // dy, y % dy
    bz, az = z // dz, z % dz

    out = jnp.zeros((tx * dx, ty * dy, tz * dz, phi.shape[3]), dtype)
    for l in range(4):
        for m in range(4):
            for n in range(4):
                g = phi[bx[:, None, None] + l, by[None, :, None] + m, bz[None, None, :] + n]
                w = (
                    wx[ax, l][:, None, None]
                    * wy[ay, m][None, :, None]
                    * wz[az, n][None, None, :]
                )
                out = out + g * w[..., None]
    return out


def bsi_tt(phi, tile, dtype=None):
    """Thread-per-tile form: tile-shared control-point slices, 64 FMA steps."""
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), c = _dims(phi, tile)
    wx, wy, wz = (weight_lut(d, dtype) for d in (dx, dy, dz))

    out = jnp.zeros((tx, dx, ty, dy, tz, dz, c), dtype)
    for l in range(4):
        for m in range(4):
            for n in range(4):
                sl = phi[l : l + tx, m : m + ty, n : n + tz]  # shared by the whole tile
                w = (
                    wx[:, l][:, None, None] * wy[:, m][None, :, None] * wz[:, n][None, None, :]
                ).reshape(1, dx, 1, dy, 1, dz, 1)
                out = out + sl[:, None, :, None, :, None, :] * w
    return out.reshape(tx * dx, ty * dy, tz * dz, c)


def _lerp(a, b, t):
    return a + t * (b - a)


def bsi_ttli(phi, tile, dtype=None):
    """TT + trilinear/lerp reformulation (paper §3.3, App. B).

    Axis-staged pairwise lerps: 3 lerps collapse the 4 x-neighbours, then y,
    then z — 63 lerps (126 FMA-class ops) per voxel, the same DAG as the
    paper's 8 sub-cubes + 1 final cube regrouping.
    """
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), c = _dims(phi, tile)
    t0x, t1x, sx = lerp_luts(dx, dtype)
    t0y, t1y, sy = lerp_luts(dy, dtype)
    t0z, t1z, sz = lerp_luts(dz, dtype)

    # x stage: (tx+3, Y, Z, C) -> (tx, dx, Y, Z, C)
    f = [phi[l : l + tx] for l in range(4)]
    r = lambda t: t[None, :, None, None, None]  # broadcast LUT over (tile, a, ...)
    h01 = _lerp(f[0][:, None], f[1][:, None], r(t0x))
    h23 = _lerp(f[2][:, None], f[3][:, None], r(t1x))
    hx = _lerp(h01, h23, r(sx))
    hx = hx.reshape(tx * dx, ty + 3, tz + 3, c)

    # y stage: (X, ty+3, Z, C) -> (X, ty, dy, Z, C)
    f = [hx[:, m : m + ty] for m in range(4)]
    r = lambda t: t[None, None, :, None, None]
    h01 = _lerp(f[0][:, :, None], f[1][:, :, None], r(t0y))
    h23 = _lerp(f[2][:, :, None], f[3][:, :, None], r(t1y))
    hy = _lerp(h01, h23, r(sy))
    hy = hy.reshape(tx * dx, ty * dy, tz + 3, c)

    # z stage
    f = [hy[:, :, n : n + tz] for n in range(4)]
    r = lambda t: t[None, None, None, :, None]
    h01 = _lerp(f[0][:, :, :, None], f[1][:, :, :, None], r(t0z))
    h23 = _lerp(f[2][:, :, :, None], f[3][:, :, :, None], r(t1z))
    hz = _lerp(h01, h23, r(sz))
    return hz.reshape(tx * dx, ty * dy, tz * dz, c)


def bsi_separable(phi, tile, dtype=None):
    """Beyond-paper separable form: three per-axis tensor contractions."""
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), c = _dims(phi, tile)
    wx, wy, wz = (weight_lut(d, dtype) for d in (dx, dy, dz))

    # x sweep: out[t, a, ...] = sum_l Wx[a, l] * phi[t + l, ...]
    px = jnp.stack([phi[l : l + tx] for l in range(4)])  # (4, tx, Y, Z, C)
    hx = jnp.einsum("al,ltyzc->tayzc", wx, px).reshape(tx * dx, ty + 3, tz + 3, c)
    py = jnp.stack([hx[:, m : m + ty] for m in range(4)])  # (4, X, ty, Z, C)
    hy = jnp.einsum("bm,mxtzc->xtbzc", wy, py).reshape(tx * dx, ty * dy, tz + 3, c)
    pz = jnp.stack([hy[:, :, n : n + tz] for n in range(4)])  # (4, X, Y, tz, C)
    hz = jnp.einsum("cn,nxytk->xytck", wz, pz)
    return hz.reshape(tx * dx, ty * dy, tz * dz, c)


def bsi_matmul(phi, tile, dtype=None):
    """Matrix form (Wu & Zou): one ``(d^3, 64) @ (64, C)`` matmul per tile.

    The 64 shifted views of the control grid become the per-tile column
    matrix; the precomputed Kronecker basis (:func:`~repro.core.bspline.
    basis_matrix`) contracts them in a single MXU-shaped ``dot_general``
    with fp32 accumulation (``preferred_element_type``) — bf16 operands
    stay bf16 in memory, products accumulate in fp32.
    """
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), c = _dims(phi, tile)
    b = basis_matrix((dx, dy, dz), dtype)  # (d^3, 64)

    win = jnp.stack([
        phi[l : l + tx, m : m + ty, n : n + tz]
        for l in range(4) for m in range(4) for n in range(4)
    ], axis=3)  # (tx, ty, tz, 64, C)
    h = jax.lax.dot_general(b, win, (((1,), (3,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = h.astype(dtype).reshape(dx, dy, dz, tx, ty, tz, c)
    h = h.transpose(3, 0, 4, 1, 5, 2, 6)
    return h.reshape(tx * dx, ty * dy, tz * dz, c)


MODES = {
    "gather": bsi_gather,
    "tt": bsi_tt,
    "ttli": bsi_ttli,
    "separable": bsi_separable,
    "matmul": bsi_matmul,
}

# The canonical mode-name set.  Every other layer that validates or
# enumerates modes (options validation, the autotuner's candidate list,
# benchmarks) derives from this tuple — do not restate the names elsewhere.
MODE_NAMES = tuple(sorted(MODES))

# Adjoint implementations for the custom-VJP gradient path: "xla" is plain
# autodiff of the forward (no custom VJP), the others are analytic adjoints —
# the separable transpose as jnp ("jnp") / as the Pallas kernel ("pallas"),
# and the transposed-matmul Pallas kernel ("matmul").
GRAD_IMPLS = ("xla", "jnp", "pallas", "matmul")


def bsi_adjoint_separable(g, tile, dtype=None):
    """Transpose of Eq. (1): dense-field cotangent -> control-grid cotangent.

    The Tucker contraction of :func:`bsi_separable` run in reverse: each axis
    sweep contracts the per-tile voxel axis against the ``(d, 4)`` weight LUT
    (one small MXU-friendly matmul) and overlap-adds the four shifted bands —
    every control point's gradient is a weighted *reduction* over its own
    ``(4*d)^3`` support window, never a scatter.  Sweeps run in reverse axis
    order (z, y, x) so intermediates shrink as early as possible.

    Args:
      g: ``(Tx*dx, Ty*dy, Tz*dz, C)`` cotangent of the dense field.
      tile: ``(dx, dy, dz)`` control-point spacing in voxels.
      dtype: accumulation/output dtype; defaults to float32 (promoted with
        ``g.dtype``) so bf16-compute forwards still accumulate in fp32.

    Returns:
      ``(Tx+3, Ty+3, Tz+3, C)`` control-grid cotangent.
    """
    dtype = dtype or jnp.promote_types(g.dtype, jnp.float32)
    dx, dy, dz = (int(t) for t in tile)
    X, Y, Z, c = g.shape
    if X % dx or Y % dy or Z % dz:
        raise ValueError(f"cotangent shape {g.shape} not a multiple of {tile}")
    tx, ty, tz = X // dx, Y // dy, Z // dz
    g = jnp.asarray(g, dtype)
    wx, wy, wz = (weight_lut(d, dtype) for d in (dx, dy, dz))

    # z sweep: (X, Y, tz*dz, C) -> (X, Y, tz+3, C).  c[t, n] = sum_a W[a, n]
    # * g[t*dz + a]; band n of the result lands at control index t + n.
    u = g.reshape(X, Y, tz, dz, c)
    cz = jnp.einsum("an,xytac->nxytc", wz, u)
    hz = sum(jnp.pad(cz[n], ((0, 0), (0, 0), (n, 3 - n), (0, 0)))
             for n in range(4))
    # y sweep
    u = hz.reshape(X, ty, dy, tz + 3, c)
    cy = jnp.einsum("am,xtazc->mxtzc", wy, u)
    hy = sum(jnp.pad(cy[m], ((0, 0), (m, 3 - m), (0, 0), (0, 0)))
             for m in range(4))
    # x sweep
    u = hy.reshape(tx, dx, ty + 3, tz + 3, c)
    cx = jnp.einsum("al,tayzc->ltyzc", wx, u)
    return sum(jnp.pad(cx[l], ((l, 3 - l), (0, 0), (0, 0), (0, 0)))
               for l in range(4))


def bsi_adjoint_matmul(g, tile, dtype=None):
    """Transposed matrix form of :func:`bsi_matmul` (jnp reference).

    ``c4[t, k] = sum_v B[v, k] * g[t, v]`` — one ``(64, d^3) @ (d^3, T*C)``
    contraction per call — followed by the 64-band shifted overlap-add that
    scatters tile ``t``'s offset-``(l, m, n)`` band onto control point
    ``t + (l, m, n)``.  Same signature and semantics as
    :func:`bsi_adjoint_separable`; a Pallas kernel of the same contraction
    lives in ``repro.kernels.bsi_adjoint`` (``grad_impl="matmul"``).
    """
    dtype = dtype or jnp.promote_types(g.dtype, jnp.float32)
    dx, dy, dz = (int(t) for t in tile)
    X, Y, Z, c = g.shape
    if X % dx or Y % dy or Z % dz:
        raise ValueError(f"cotangent shape {g.shape} not a multiple of {tile}")
    tx, ty, tz = X // dx, Y // dy, Z // dz
    g = jnp.asarray(g, dtype)
    b = basis_matrix((dx, dy, dz), dtype)  # (d^3, 64)

    u = g.reshape(tx, dx, ty, dy, tz, dz, c).transpose(0, 2, 4, 1, 3, 5, 6)
    u = u.reshape(tx, ty, tz, dx * dy * dz, c)
    c4 = jax.lax.dot_general(b, u, (((0,), (3,)), ((), ())),
                             preferred_element_type=jnp.float32)
    c4 = c4.astype(dtype).reshape(4, 4, 4, tx, ty, tz, c)
    return sum(
        jnp.pad(c4[l, m, n], ((l, 3 - l), (m, 3 - m), (n, 3 - n), (0, 0)))
        for l in range(4) for m in range(4) for n in range(4))


@functools.partial(jax.jit, static_argnames=("tile", "impl", "dtype_name"))
def _adjoint_jit(g, tile, impl, dtype_name):
    dtype = jnp.dtype(dtype_name) if dtype_name else None
    if impl == "jnp":
        return bsi_adjoint_separable(g, tile, dtype)
    if impl in ("pallas", "matmul"):
        from repro.kernels import ops  # local import: kernels import this module

        form = "separable" if impl == "pallas" else "matmul"
        return ops.bsi_adjoint_pallas(g, tile, dtype=dtype, form=form)
    raise ValueError(f"unknown adjoint impl {impl!r}")


def bsi_adjoint(g, tile, *, impl="jnp", dtype=None):
    """Dispatch the analytic BSI adjoint (see :func:`bsi_adjoint_separable`).

    ``impl``: ``jnp`` (reference separable-transpose), ``pallas`` (the
    VMEM-tiled separable-transpose kernel in ``repro.kernels.bsi_adjoint``)
    or ``matmul`` (the transposed-matmul kernel in the same module).
    """
    name = jnp.dtype(dtype).name if dtype is not None else None
    return _adjoint_jit(g, tuple(int(t) for t in tile), impl, name)


@functools.partial(jax.jit, static_argnames=("tile", "mode", "impl", "dtype_name"))
def _interpolate_jit(phi, tile, mode, impl, dtype_name):
    dtype = jnp.dtype(dtype_name) if dtype_name else None
    if impl == "jnp":
        return MODES[mode](phi, tile, dtype)
    if impl == "pallas":
        from repro.kernels import ops  # local import: kernels import this module

        return ops.bsi_pallas(phi, tile, mode=mode, dtype=dtype)
    raise ValueError(f"unknown impl {impl!r}")


@functools.lru_cache(maxsize=None)
def _custom_vjp_interp(tile, mode, impl, grad_impl, dtype_name, in_dtype_name):
    """Build (and cache) the custom-VJP interpolation for one configuration.

    BSI is linear in ``phi``, so the VJP needs no residuals: the backward is
    the analytic adjoint applied to the cotangent alone, accumulated in fp32
    and cast back to the primal dtype (fp32 params keep fp32 gradients even
    when the forward computes in bf16).
    """

    @jax.custom_vjp
    def f(phi):
        return _interpolate_jit(phi, tile, mode, impl, dtype_name)

    def fwd(phi):
        return f(phi), None

    def bwd(_, g):
        dphi = _adjoint_jit(g, tile, grad_impl, None)
        return (dphi.astype(in_dtype_name),)

    f.defvjp(fwd, bwd)
    return f


def interpolate(phi, tile, *, mode="separable", impl="jnp", dtype=None,
                grad_impl="xla"):
    """Interpolate a control grid to a dense field.

    Args:
      phi: ``(Tx+3, Ty+3, Tz+3, C)`` control grid (aligned, +1 offset).
      tile: ``(dx, dy, dz)`` control-point spacing in voxels.
      mode: one of ``MODE_NAMES`` (``gather | matmul | separable | tt |
        ttli``).
      impl: ``jnp`` (XLA-fused reference forms) or ``pallas`` (TPU kernels;
        runs under ``interpret=True`` on CPU).
      dtype: optional compute dtype (e.g. ``bfloat16``); the output takes
        this dtype, gradients stay in ``phi.dtype``.
      grad_impl: how this call differentiates (module docstring, "Gradient
        path"): ``xla`` = plain autodiff of the forward, ``jnp`` / ``pallas``
        = ``jax.custom_vjp`` with the analytic gather-only adjoint.  With a
        non-``xla`` choice the Pallas forward kernels become differentiable.
    Returns:
      ``(Tx*dx, Ty*dy, Tz*dz, C)`` dense field.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; choose from {MODE_NAMES}")
    if grad_impl not in GRAD_IMPLS:
        raise ValueError(
            f"unknown grad_impl {grad_impl!r}; choose from {GRAD_IMPLS}")
    name = jnp.dtype(dtype).name if dtype is not None else None
    tile = tuple(int(t) for t in tile)
    if grad_impl == "xla":
        return _interpolate_jit(phi, tile, mode, impl, name)
    f = _custom_vjp_interp(tile, mode, impl, grad_impl, name,
                           jnp.dtype(phi.dtype).name)
    return f(phi)
