"""B-spline interpolation — public API and the jnp-level algorithm forms.

Three algorithmic forms of paper Eq. (1), mirroring the paper's comparison
matrix (§5), plus a mode dispatcher.  Each form exists twice in the repo:

* here as a pure-jnp implementation — these are the *CPU analogs* (the paper's
  Fig. 7 VT/VV role) and the reference semantics;
* in ``repro.kernels`` as a Pallas TPU kernel with explicit VMEM tiling
  (``bsi_tt``, ``bsi_ttli``, ``bsi_separable``) — the paper's GPU kernels,
  adapted to TPU (DESIGN.md §2).

Forms
-----
``gather``      thread-per-voxel analog (NiftyReg-TV baseline): every voxel
                gathers its 64 control points and weight-sums them.  Maximal
                redundant data movement — the paper's comparison baseline.
``tt``          thread-per-tile: tile-shared slices of the control grid are
                broadcast over the tile's voxels; 64 FMA accumulation steps.
``ttli``        tt + the trilinear/lerp reformulation (126 ops/voxel vs 255).
``separable``   beyond-paper tensor-contraction form: the per-tile sum is a
                Tucker contraction -> three small matmuls (MXU-friendly),
                ~(4/d + 4/d^2 + 4/d^3) MACs/voxel instead of 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bspline import lerp_luts, weight_lut

__all__ = ["bsi_gather", "bsi_tt", "bsi_ttli", "bsi_separable", "interpolate", "MODES"]


def _dims(phi, tile):
    dx, dy, dz = (int(t) for t in tile)
    tx, ty, tz = (int(n) - 3 for n in phi.shape[:3])
    if min(tx, ty, tz) < 1:
        raise ValueError(f"control grid {phi.shape} too small for any tile")
    return (dx, dy, dz), (tx, ty, tz), phi.shape[3]


def bsi_gather(phi, tile, dtype=None):
    """Thread-per-voxel analog: per-voxel 64-point gather + weighted sum."""
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), _ = _dims(phi, tile)
    wx, wy, wz = (weight_lut(d, dtype) for d in (dx, dy, dz))

    x = jnp.arange(tx * dx)
    y = jnp.arange(ty * dy)
    z = jnp.arange(tz * dz)
    bx, ax = x // dx, x % dx
    by, ay = y // dy, y % dy
    bz, az = z // dz, z % dz

    out = jnp.zeros((tx * dx, ty * dy, tz * dz, phi.shape[3]), dtype)
    for l in range(4):
        for m in range(4):
            for n in range(4):
                g = phi[bx[:, None, None] + l, by[None, :, None] + m, bz[None, None, :] + n]
                w = (
                    wx[ax, l][:, None, None]
                    * wy[ay, m][None, :, None]
                    * wz[az, n][None, None, :]
                )
                out = out + g * w[..., None]
    return out


def bsi_tt(phi, tile, dtype=None):
    """Thread-per-tile form: tile-shared control-point slices, 64 FMA steps."""
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), c = _dims(phi, tile)
    wx, wy, wz = (weight_lut(d, dtype) for d in (dx, dy, dz))

    out = jnp.zeros((tx, dx, ty, dy, tz, dz, c), dtype)
    for l in range(4):
        for m in range(4):
            for n in range(4):
                sl = phi[l : l + tx, m : m + ty, n : n + tz]  # shared by the whole tile
                w = (
                    wx[:, l][:, None, None] * wy[:, m][None, :, None] * wz[:, n][None, None, :]
                ).reshape(1, dx, 1, dy, 1, dz, 1)
                out = out + sl[:, None, :, None, :, None, :] * w
    return out.reshape(tx * dx, ty * dy, tz * dz, c)


def _lerp(a, b, t):
    return a + t * (b - a)


def bsi_ttli(phi, tile, dtype=None):
    """TT + trilinear/lerp reformulation (paper §3.3, App. B).

    Axis-staged pairwise lerps: 3 lerps collapse the 4 x-neighbours, then y,
    then z — 63 lerps (126 FMA-class ops) per voxel, the same DAG as the
    paper's 8 sub-cubes + 1 final cube regrouping.
    """
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), c = _dims(phi, tile)
    t0x, t1x, sx = lerp_luts(dx, dtype)
    t0y, t1y, sy = lerp_luts(dy, dtype)
    t0z, t1z, sz = lerp_luts(dz, dtype)

    # x stage: (tx+3, Y, Z, C) -> (tx, dx, Y, Z, C)
    f = [phi[l : l + tx] for l in range(4)]
    r = lambda t: t[None, :, None, None, None]  # broadcast LUT over (tile, a, ...)
    h01 = _lerp(f[0][:, None], f[1][:, None], r(t0x))
    h23 = _lerp(f[2][:, None], f[3][:, None], r(t1x))
    hx = _lerp(h01, h23, r(sx))
    hx = hx.reshape(tx * dx, ty + 3, tz + 3, c)

    # y stage: (X, ty+3, Z, C) -> (X, ty, dy, Z, C)
    f = [hx[:, m : m + ty] for m in range(4)]
    r = lambda t: t[None, None, :, None, None]
    h01 = _lerp(f[0][:, :, None], f[1][:, :, None], r(t0y))
    h23 = _lerp(f[2][:, :, None], f[3][:, :, None], r(t1y))
    hy = _lerp(h01, h23, r(sy))
    hy = hy.reshape(tx * dx, ty * dy, tz + 3, c)

    # z stage
    f = [hy[:, :, n : n + tz] for n in range(4)]
    r = lambda t: t[None, None, None, :, None]
    h01 = _lerp(f[0][:, :, :, None], f[1][:, :, :, None], r(t0z))
    h23 = _lerp(f[2][:, :, :, None], f[3][:, :, :, None], r(t1z))
    hz = _lerp(h01, h23, r(sz))
    return hz.reshape(tx * dx, ty * dy, tz * dz, c)


def bsi_separable(phi, tile, dtype=None):
    """Beyond-paper separable form: three per-axis tensor contractions."""
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    (dx, dy, dz), (tx, ty, tz), c = _dims(phi, tile)
    wx, wy, wz = (weight_lut(d, dtype) for d in (dx, dy, dz))

    # x sweep: out[t, a, ...] = sum_l Wx[a, l] * phi[t + l, ...]
    px = jnp.stack([phi[l : l + tx] for l in range(4)])  # (4, tx, Y, Z, C)
    hx = jnp.einsum("al,ltyzc->tayzc", wx, px).reshape(tx * dx, ty + 3, tz + 3, c)
    py = jnp.stack([hx[:, m : m + ty] for m in range(4)])  # (4, X, ty, Z, C)
    hy = jnp.einsum("bm,mxtzc->xtbzc", wy, py).reshape(tx * dx, ty * dy, tz + 3, c)
    pz = jnp.stack([hy[:, :, n : n + tz] for n in range(4)])  # (4, X, Y, tz, C)
    hz = jnp.einsum("cn,nxytk->xytck", wz, pz)
    return hz.reshape(tx * dx, ty * dy, tz * dz, c)


MODES = {
    "gather": bsi_gather,
    "tt": bsi_tt,
    "ttli": bsi_ttli,
    "separable": bsi_separable,
}


@functools.partial(jax.jit, static_argnames=("tile", "mode", "impl", "dtype_name"))
def _interpolate_jit(phi, tile, mode, impl, dtype_name):
    dtype = jnp.dtype(dtype_name) if dtype_name else None
    if impl == "jnp":
        return MODES[mode](phi, tile, dtype)
    if impl == "pallas":
        from repro.kernels import ops  # local import: kernels import this module

        return ops.bsi_pallas(phi, tile, mode=mode, dtype=dtype)
    raise ValueError(f"unknown impl {impl!r}")


def interpolate(phi, tile, *, mode="separable", impl="jnp", dtype=None):
    """Interpolate a control grid to a dense field.

    Args:
      phi: ``(Tx+3, Ty+3, Tz+3, C)`` control grid (aligned, +1 offset).
      tile: ``(dx, dy, dz)`` control-point spacing in voxels.
      mode: one of ``gather | tt | ttli | separable``.
      impl: ``jnp`` (XLA-fused reference forms) or ``pallas`` (TPU kernels;
        runs under ``interpret=True`` on CPU).
    Returns:
      ``(Tx*dx, Ty*dy, Tz*dz, C)`` dense field.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; choose from {sorted(MODES)}")
    name = jnp.dtype(dtype).name if dtype is not None else None
    return _interpolate_jit(phi, tuple(int(t) for t in tile), mode, impl, name)
