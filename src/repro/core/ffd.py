"""Free-Form Deformation: control grid -> dense deformation field -> warp.

The FFD transform (Rueckert et al. 1999, as used by NiftyReg and the paper)
manipulates a coarse uniform grid of 3-vector control points; BSI expands it
to a dense per-voxel displacement field; the moving volume is resampled at the
displaced coordinates (trilinear image resampling, NiftyReg's default).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.interpolate import interpolate

__all__ = [
    "grid_shape_for_volume",
    "dense_field",
    "fused_warp_loss",
    "trilinear_sample",
    "warp_volume",
    "bending_energy",
    "downsample2",
    "upsample_grid",
]


def grid_shape_for_volume(vol_shape, tile) -> tuple:
    """Stored control-grid dims covering ``vol_shape`` at spacing ``tile``."""
    return tuple(-(-int(s) // int(d)) + 3 for s, d in zip(vol_shape, tile))


def downsample2(vol):
    """2x average-pool downsampling (pyramid level)."""
    X, Y, Z = (s - s % 2 for s in vol.shape)
    v = vol[:X, :Y, :Z].reshape(X // 2, 2, Y // 2, 2, Z // 2, 2)
    return v.mean(axis=(1, 3, 5))


def upsample_grid(phi, new_shape):
    """Upsample a control grid to a finer level's grid shape (trilinear).

    One batched ``trilinear_sample`` (``vmap`` over the displacement channel)
    so pyramid-level promotion compiles to a single gather instead of a
    per-channel Python loop.
    """
    old = phi.shape[:3]
    coords = jnp.stack(
        jnp.meshgrid(
            *[jnp.linspace(0.0, o - 1.0, n) for o, n in zip(old, new_shape)],
            indexing="ij",
        ),
        axis=-1,
    )
    comps = jax.vmap(trilinear_sample, in_axes=(3, None), out_axes=3)(
        phi, coords)
    return comps * 2.0  # displacements double at 2x res


def dense_field(phi, tile, vol_shape, *, mode="separable", impl="jnp",
                grad_impl="xla", compute_dtype=None):
    """Expand control grid to a dense displacement field cropped to volume.

    ``grad_impl`` selects how the expansion differentiates (``xla`` = plain
    autodiff; ``jnp`` / ``pallas`` = the analytic gather-only adjoint via
    ``jax.custom_vjp`` — see ``repro.core.interpolate``).  ``compute_dtype``
    (e.g. ``bfloat16``) runs the interpolation in reduced precision while
    params and the analytic adjoints' accumulation stay fp32; an *explicit*
    ``grad_impl="xla"`` is the one combination whose backward follows the
    compute dtype instead (plain autodiff of the reduced-precision forward
    — the engine's ``"auto"`` therefore never picks it under a reduced
    ``compute_dtype``).
    """
    full = interpolate(phi, tile, mode=mode, impl=impl, grad_impl=grad_impl,
                       dtype=compute_dtype)
    return full[: vol_shape[0], : vol_shape[1], : vol_shape[2]]


def fused_warp_loss(phi, moving, fixed, tile, *, similarity="ssd",
                    mode="separable", impl="jnp", grad_impl="xla",
                    compute_dtype=None, interpret=None):
    """``sim(warp(moving, bsi(phi)), fixed)`` without a dense field in HBM.

    The differentiable face of the fused level step: the forward runs the
    single-pass Pallas kernel (``kernels.ops.fused_similarity_loss`` — BSI
    displacement + trilinear warp + similarity partial sums per VMEM block),
    and a ``jax.custom_vjp`` backward recomputes the unfused composition
    ``dense_field -> warp_volume -> sim`` under ``jax.vjp`` so the gradient
    flows through PR 4's analytic gather-only adjoint (``grad_impl``) —
    gradients are therefore *identical* to the unfused path, not merely
    close.  ``similarity`` must have a fused accumulator
    (``core.similarity.fused_spec``); custom callables raise.

    ``impl`` / ``grad_impl`` configure only the backward's recompute;
    ``mode`` also selects the fused forward's displacement stage —
    ``mode="matmul"`` runs the megakernel's BSI contraction in the MXU
    matrix form (``kernels.bsi_fused._disp_block(form="matmul")``), every
    other mode runs the separable sweeps (the kernel's two contraction
    forms; both produce the same displacement).  ``compute_dtype``
    quantises the displacement and the sampled intensities exactly as the
    unfused pair of knobs does, with fp32 partial-sum accumulation.
    """
    from repro.core.similarity import fused_spec

    spec = fused_spec(similarity)
    if spec is None:
        raise ValueError(
            f"similarity {similarity!r} has no fused kernel — custom "
            "callables must run unfused (fused='off')")
    cd = None if compute_dtype is None else jnp.dtype(compute_dtype).name
    f = _fused_objective(tuple(int(t) for t in tile), tuple(spec),
                         str(mode), str(impl), str(grad_impl), cd,
                         None if interpret is None else bool(interpret))
    return f(phi, moving, fixed)


@functools.lru_cache(maxsize=None)
def _fused_objective(tile, spec, mode, impl, grad_impl, cdtype, interpret):
    from repro.core.similarity import _loss_from_spec
    from repro.kernels import ops

    sim = _loss_from_spec(spec)

    def unfused(p, mov, fix):
        disp = dense_field(p, tile, mov.shape, mode=mode, impl=impl,
                           grad_impl=grad_impl, compute_dtype=cdtype)
        warped = warp_volume(mov, disp, compute_dtype=cdtype)
        return sim(warped.astype(jnp.float32), fix.astype(jnp.float32))

    disp_form = "matmul" if mode == "matmul" else "separable"

    @jax.custom_vjp
    def fused(p, mov, fix):
        return ops.fused_similarity_loss(p, mov, fix, tile, sim_spec=spec,
                                         compute_dtype=cdtype,
                                         interpret=interpret,
                                         disp_form=disp_form)

    def fwd(p, mov, fix):
        return fused(p, mov, fix), (p, mov, fix)

    def bwd(res, g):
        # recompute-based backward: unused cotangents (mov/fix are data,
        # not optimisation variables) are dead code XLA prunes
        _, vjp = jax.vjp(unfused, *res)
        return vjp(g)

    fused.defvjp(fwd, bwd)
    return fused


def trilinear_sample(vol, coords):
    """Sample ``vol`` (X, Y, Z) at continuous voxel coords ``(..., 3)``.

    Border policy: clamp (NiftyReg uses nearest/zero padding; clamp keeps the
    objective smooth for autodiff).
    """
    vol = jnp.asarray(vol)
    shape = jnp.asarray(vol.shape, coords.dtype)
    c = jnp.clip(coords, 0.0, shape - 1.0)
    f = jnp.floor(c)
    t = c - f
    i0 = f.astype(jnp.int32)
    i1 = jnp.minimum(i0 + 1, jnp.asarray(vol.shape, jnp.int32) - 1)

    def at(ix, iy, iz):
        return vol[ix, iy, iz]

    x0, y0, z0 = i0[..., 0], i0[..., 1], i0[..., 2]
    x1, y1, z1 = i1[..., 0], i1[..., 1], i1[..., 2]
    tx, ty, tz = t[..., 0], t[..., 1], t[..., 2]
    c00 = at(x0, y0, z0) * (1 - tx) + at(x1, y0, z0) * tx
    c01 = at(x0, y0, z1) * (1 - tx) + at(x1, y0, z1) * tx
    c10 = at(x0, y1, z0) * (1 - tx) + at(x1, y1, z0) * tx
    c11 = at(x0, y1, z1) * (1 - tx) + at(x1, y1, z1) * tx
    c0 = c00 * (1 - ty) + c10 * ty
    c1 = c01 * (1 - ty) + c11 * ty
    return c0 * (1 - tz) + c1 * tz


def warp_volume(moving, disp, compute_dtype=None):
    """Resample ``moving`` at identity + displacement (both in voxel units).

    ``compute_dtype`` (e.g. ``bfloat16``) casts the sampled *intensities*
    (the memory-bound gather) — the mixed-precision partner of
    ``dense_field``'s knob; the caller decides where to cast back up
    (``engine.batch.ffd_level_loss`` scores the objective in the fixed
    volume's dtype).  Sampling *coordinates* always stay fp32: bf16 cannot
    represent integers above 256, so a bf16 identity grid would shift
    sampling positions by whole voxels on paper-scale (>256-voxel) volumes.
    """
    coord_dtype = jnp.promote_types(disp.dtype, jnp.float32)
    if compute_dtype is not None:
        moving = jnp.asarray(moving, compute_dtype)
    disp = jnp.asarray(disp, coord_dtype)
    X, Y, Z = moving.shape
    xs = jnp.arange(X, dtype=coord_dtype)
    ys = jnp.arange(Y, dtype=coord_dtype)
    zs = jnp.arange(Z, dtype=coord_dtype)
    ident = jnp.stack(jnp.meshgrid(xs, ys, zs, indexing="ij"), axis=-1)
    return trilinear_sample(moving, ident + disp)


def bending_energy(phi):
    """Thin-plate bending energy of the control grid (NiftyReg regulariser).

    Second-order finite differences on the control lattice — a standard,
    cheap surrogate for the analytic B-spline bending energy.
    """
    e = 0.0
    for ax in range(3):
        d2 = jnp.diff(phi, n=2, axis=ax)
        e = e + jnp.mean(d2**2)
    # mixed second derivatives
    for a in range(3):
        for b in range(a + 1, 3):
            d = jnp.diff(jnp.diff(phi, axis=a), axis=b)
            e = e + 2.0 * jnp.mean(d**2)
    return e
