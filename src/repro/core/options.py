"""Unified registration options — one frozen, hashable configuration object.

Every registration entry point used to take the same ~12-keyword sprawl
(``tile, levels, iters, lr, bending_weight, mode, impl, grad_impl,
compute_dtype, similarity, stop``), and each one re-validated and re-keyed
the subset it cared about.  :class:`RegistrationOptions` consolidates that
surface:

* it is the **single place options are validated** (``__post_init__``) and
  canonicalised (:meth:`normalized`);
* because it is frozen and hashable, it is the **single cache key** for
  compiled runners (``core.registration``, ``engine.batch``), the autotuner
  (``engine.autotune.resolve_options``) and the serving buckets
  (``engine.serve``);
* entry points accept ``options=RegistrationOptions(...)``.  The legacy
  keyword arguments still work through :func:`merge_legacy_options`, which
  emits a ``DeprecationWarning`` once per call site and produces the exact
  same options object — so the kwarg path and the options path share one
  compiled program and return bit-identical results.

This module deliberately imports nothing from ``repro`` at module scope
(only lazily, inside methods): it sits at the bottom of the dependency
stack so both ``repro.core`` and ``repro.engine`` can import it freely.
"""

from __future__ import annotations

import dataclasses
import sys
import warnings
from typing import Any

__all__ = [
    "UNSET",
    "RegistrationOptions",
    "merge_legacy_options",
]


class _Unset:
    """Sentinel distinguishing "keyword not passed" from an explicit value."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):
        return "UNSET"

    def __bool__(self):
        return False


UNSET = _Unset()

_BSI_IMPLS = ("auto", "jnp", "pallas")
_FUSED = ("auto", "on", "off")


def _bsi_modes():
    """``("auto",)`` + the canonical mode set.

    Derived lazily from ``repro.core.interpolate.MODE_NAMES`` — the single
    source every layer validates against — so a new mode registers here
    without a drifting duplicate list (this module keeps repro imports out
    of module scope; see ``__post_init__``'s registry imports).
    """
    from repro.core.interpolate import MODE_NAMES

    return ("auto",) + MODE_NAMES


def _grad_impls():
    """``("auto",)`` + ``repro.core.interpolate.GRAD_IMPLS`` (same rule)."""
    from repro.core.interpolate import GRAD_IMPLS

    return ("auto",) + GRAD_IMPLS


@dataclasses.dataclass(frozen=True)
class RegistrationOptions:
    """The full registration configuration, validated and hashable.

    Defaults match the historical ``ffd_register`` / ``register_batch``
    keyword defaults; ``affine_register`` keeps its own legacy defaults
    (``iters=60, lr=0.02``) through its deprecation shim.

    Fields
    ------
    tile:            control-point spacing ``(dx, dy, dz)``.
    levels:          pyramid levels (coarse-to-fine, 2x downsampling).
    iters:           Adam steps per level (also the early-stop ceiling).
    lr:              Adam learning rate.
    bending_weight:  bending-energy regularisation weight.
    mode, impl:      BSI algorithm form / kernel backend (``"auto"`` =
                     the ``engine.autotune`` winner).
    grad_impl:       BSI adjoint implementation (``"auto"`` | ``"xla"`` |
                     ``"jnp"`` | ``"pallas"`` | ``"matmul"``).
    compute_dtype:   reduced-precision dtype for BSI + warp (e.g.
                     ``"bfloat16"``), or None for fp32 throughout.
    similarity:      registered similarity name or a ``(warped, fixed) ->
                     scalar`` loss callable (lower = better).
    transform:       transform model: registered name (``"displacement"`` |
                     ``"velocity"``) or a frozen spec from
                     ``repro.core.transform`` (e.g.
                     ``velocity(squarings=4)``).  ``"velocity"`` integrates
                     a stationary velocity field by scaling and squaring —
                     invertible, fold-free deformations for the IGS-safety
                     workloads; names normalise to their spec instance.
    regularizer:     registered name (``"none"`` | ``"bending"``) or a
                     frozen spec from ``repro.core.regularizer``.
                     ``"none"`` keeps the historical ``bending_weight``
                     finite-difference proxy; ``"bending"`` replaces it
                     with the analytic uniform-cubic-B-spline bending
                     energy (weight via ``bending(weight=...)``).
    stop:            optional ``engine.convergence.ConvergenceConfig`` —
                     early-stop each level when the loss plateaus.
    fused:           fused level-step kernel (``core.ffd.fused_warp_loss``:
                     BSI + warp + similarity in one VMEM Pallas pass, no
                     dense field in HBM).  ``"auto"`` lets the autotuner
                     race it against the unfused step per backend (custom
                     similarities and over-budget volumes fall back to
                     ``"off"``); ``"on"`` forces it (raising when
                     unsupported); ``"off"`` is the unfused pipeline.
    optimizer:       registered optimiser name (``"adam"`` | ``"lbfgs"`` |
                     ``"gauss_newton"``) or a frozen spec from
                     ``repro.engine.optimizer`` (e.g. ``lbfgs(history=10)``);
                     names normalise to their spec instance.  The default
                     ``"adam"`` is bit-identical to the pre-registry engine;
                     ``"gauss_newton"`` requires ``similarity="ssd"`` (the
                     only built-in with a least-squares residual form) and
                     an unfused level step (the fused megakernel's
                     partial-sum accumulator never materialises the
                     residual volume).
    fused_reason:    why ``fused`` resolved the way it did — set by
                     ``engine.autotune.resolve_options`` on its output
                     (e.g. ``"forced on"``, ``"velocity transform has no
                     fused composition"``, ``"autotune: fused won"``),
                     ``None`` on hand-built unresolved options.  Excluded
                     from equality/hash on purpose: it is introspection
                     metadata, not configuration, so it never fragments a
                     program cache.
    """

    tile: tuple = (5, 5, 5)
    levels: int = 2
    iters: int = 40
    lr: float = 0.5
    bending_weight: float = 5e-3
    mode: str = "auto"
    impl: str = "auto"
    grad_impl: str = "auto"
    compute_dtype: Any = None
    similarity: Any = "ssd"
    transform: Any = "displacement"
    regularizer: Any = "none"
    stop: Any = None
    fused: str = "auto"
    optimizer: Any = "adam"
    fused_reason: Any = dataclasses.field(default=None, compare=False)

    def __post_init__(self):
        tile = tuple(int(t) for t in self.tile)
        if len(tile) != 3 or any(t < 1 for t in tile):
            raise ValueError(f"tile must be 3 positive ints, got {self.tile!r}")
        object.__setattr__(self, "tile", tile)
        for name in ("levels", "iters"):
            v = int(getattr(self, name))
            if v < 1:
                raise ValueError(f"{name} must be >= 1, got {v}")
            object.__setattr__(self, name, v)
        for name in ("lr", "bending_weight"):
            v = float(getattr(self, name))
            if not v >= 0 or (name == "lr" and v == 0):
                raise ValueError(f"{name} must be positive, got {v}")
            object.__setattr__(self, name, v)
        modes = _bsi_modes()
        if self.mode not in modes:
            raise ValueError(f"mode must be one of {modes}, got {self.mode!r}")
        if self.impl not in _BSI_IMPLS:
            raise ValueError(f"impl must be one of {_BSI_IMPLS}, got {self.impl!r}")
        grad_impls = _grad_impls()
        if self.grad_impl not in grad_impls:
            raise ValueError(
                f"grad_impl must be one of {grad_impls}, got {self.grad_impl!r}"
            )
        if self.fused in (True, False):  # ergonomic bool spelling
            object.__setattr__(self, "fused", "on" if self.fused else "off")
        if self.fused not in _FUSED:
            raise ValueError(
                f"fused must be one of {_FUSED} (or a bool), got {self.fused!r}"
            )
        if self.compute_dtype is not None:
            import jax.numpy as jnp

            object.__setattr__(
                self, "compute_dtype", jnp.dtype(self.compute_dtype).name
            )
        if not (callable(self.similarity) or isinstance(self.similarity, str)):
            raise TypeError(
                "similarity must be a registered name or a loss callable, "
                f"got {self.similarity!r}"
            )
        # Canonicalise transform/regularizer to their frozen spec instances
        # (same discipline as the fused bool -> "on"/"off" normalisation):
        # "velocity" and velocity() hash equal, and the spec instance is the
        # sole program-cache key downstream.
        from repro.core.regularizer import resolve_regularizer
        from repro.core.transform import VelocityTransform, resolve_transform

        object.__setattr__(self, "transform", resolve_transform(self.transform))
        object.__setattr__(
            self, "regularizer", resolve_regularizer(self.regularizer)
        )
        if self.fused == "on" and isinstance(self.transform, VelocityTransform):
            raise ValueError(
                "fused='on' is incompatible with transform='velocity': the "
                "fused level-step kernel evaluates BSI + warp + similarity "
                "in one pass and cannot interleave the scaling-and-squaring "
                "compositions the velocity transform needs; use fused='auto' "
                "or 'off' (velocity always runs the unfused pipeline)"
            )
        # Canonicalise the optimiser to its frozen spec instance (same
        # discipline): "lbfgs" and lbfgs() hash equal, and the spec is the
        # optimiser token in every downstream program-cache key.
        from repro.engine.optimizer import (GaussNewtonOptimizer,
                                            resolve_optimizer)

        object.__setattr__(self, "optimizer", resolve_optimizer(self.optimizer))
        if isinstance(self.optimizer, GaussNewtonOptimizer):
            from repro.core.similarity import resolve_similarity

            sim_key, _ = resolve_similarity(self.similarity)
            if sim_key != "ssd":
                raise ValueError(
                    "optimizer='gauss_newton' needs the least-squares "
                    "residual form only similarity='ssd' provides, got "
                    f"similarity={self.similarity!r}; use optimizer='lbfgs' "
                    "for non-least-squares similarities"
                )
            if self.fused == "on":
                raise ValueError(
                    "fused='on' is incompatible with optimizer="
                    "'gauss_newton': the fused level step accumulates the "
                    "similarity as in-VMEM partial sums and never "
                    "materialises the residual volume Gauss-Newton "
                    "linearises; use fused='auto' or 'off'"
                )
        if self.stop is not None:
            from repro.engine.convergence import ConvergenceConfig

            if not isinstance(self.stop, ConvergenceConfig):
                raise TypeError(
                    f"stop must be a ConvergenceConfig or None, got {self.stop!r}; "
                    "e.g. stop=ConvergenceConfig(tol=1e-4)"
                )

    def replace(self, **changes) -> "RegistrationOptions":
        """A copy with the given fields replaced (re-validated)."""
        return dataclasses.replace(self, **changes)

    def normalized(self) -> "RegistrationOptions":
        """Canonical form: the cache-key-ready copy.

        ``similarity`` collapses to its registry key (so ``"nmi"`` and a
        registered ``nmi()`` callable share caches), and ``stop`` resolves
        its ``max_iters`` against ``iters`` — after this, equal
        configurations compare (and hash) equal.
        """
        from repro.core.similarity import resolve_similarity
        from repro.engine.convergence import check_stop

        sim_key, _ = resolve_similarity(self.similarity)
        return dataclasses.replace(
            self, similarity=sim_key, stop=check_stop(self.stop, self.iters)
        )

    def for_affine(self) -> "RegistrationOptions":
        """Canonical key for the affine path.

        Affine registration only consumes ``iters``, ``lr``, ``similarity``
        and ``stop``; pinning every FFD-only field to its default keeps the
        affine runner cache from fragmenting when callers vary e.g. ``tile``.
        """
        base = RegistrationOptions()
        return self.normalized().replace(
            tile=base.tile,
            levels=base.levels,
            bending_weight=base.bending_weight,
            mode=base.mode,
            impl=base.impl,
            grad_impl=base.grad_impl,
            compute_dtype=base.compute_dtype,
            transform=base.transform,
            regularizer=base.regularizer,
            fused="off",  # affine has no FFD level step to fuse
        )


# DeprecationWarning bookkeeping: one warning per (entry point, call site),
# deterministic regardless of the process's warning filters.  Tests reset it
# via _reset_deprecation_registry().
_WARNED_SITES: set = set()


def _reset_deprecation_registry():
    _WARNED_SITES.clear()


def merge_legacy_options(
    fn_name, options, legacy: dict, *, defaults=None, stacklevel=3
) -> RegistrationOptions:
    """The deprecation shim behind every registration entry point.

    ``legacy`` maps field name -> value-or-:data:`UNSET` for the keyword
    arguments the entry point still accepts.  Exactly one of the two paths
    may be used:

    * ``options=`` given, no legacy kwargs -> ``options`` passes through;
    * legacy kwargs (or nothing) -> they overlay ``defaults`` into a fresh
      :class:`RegistrationOptions`, and — if any legacy kwarg was actually
      passed — a ``DeprecationWarning`` fires, once per call site.

    Mixing both raises ``TypeError`` (silently preferring one would make the
    other a no-op).
    """
    passed = {k: v for k, v in legacy.items() if v is not UNSET}
    if options is not None:
        if not isinstance(options, RegistrationOptions):
            raise TypeError(
                f"{fn_name}: options must be a RegistrationOptions, "
                f"got {type(options).__name__}"
            )
        if passed:
            raise TypeError(
                f"{fn_name}: pass either options= or the legacy keyword "
                f"arguments {sorted(passed)}, not both"
            )
        return options
    if passed:
        frame = sys._getframe(stacklevel - 1)
        site = (fn_name, frame.f_code.co_filename, frame.f_lineno)
        if site not in _WARNED_SITES:
            _WARNED_SITES.add(site)
            spelled = ", ".join(f"{k}=..." for k in sorted(passed))
            warnings.warn(
                f"{fn_name}: the keyword arguments {sorted(passed)} are "
                f"deprecated; pass options=RegistrationOptions({spelled}) "
                "instead (see repro.core.options)",
                DeprecationWarning,
                stacklevel=stacklevel,
            )
    base = RegistrationOptions() if defaults is None else defaults
    return base.replace(**passed) if passed else base
