"""Cubic B-spline basis functions and the aligned-grid weight LUTs.

Conventions (shared by every BSI implementation in this repo)
-------------------------------------------------------------
* A volume of ``T`` tiles per axis with tile size ``delta`` has ``T * delta``
  voxels per axis.
* The control grid is *voxel aligned and uniformly spaced* (the NiftyReg
  convention the paper assumes, §3.4): voxel ``x = t*delta + a`` has
  fractional coordinate ``u = a/delta`` and base index ``i = t - 1``.
* Control grids are stored with a +1 index offset so that tile ``t`` reads
  stored points ``[t, t+4)``; a grid of ``T`` tiles therefore stores
  ``T + 3`` points per axis.
* Because the grid is aligned, ``u`` takes only ``delta`` distinct values per
  axis -> all weights live in a ``(delta, 4)`` look-up table (paper §3.4
  stores these in constant memory; we pass them as tiny operands).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

__all__ = [
    "bspline_basis",
    "weight_lut",
    "basis_matrix",
    "lerp_luts",
    "grid_points_for_tiles",
]


def bspline_basis(u, dtype=jnp.float32):
    """The four cubic B-spline basis values ``B_0..B_3`` at parameter ``u``.

    Returns an array of shape ``u.shape + (4,)``.  The basis is a partition of
    unity: ``sum_l B_l(u) == 1`` for all ``u`` — several reformulations below
    (and the TTLI lerp form) rely on this.
    """
    u = jnp.asarray(u, dtype)
    one = jnp.asarray(1.0, dtype)
    b0 = (one - u) ** 3 / 6.0
    b1 = (3.0 * u**3 - 6.0 * u**2 + 4.0) / 6.0
    b2 = (-3.0 * u**3 + 3.0 * u**2 + 3.0 * u + 1.0) / 6.0
    b3 = u**3 / 6.0
    return jnp.stack([b0, b1, b2, b3], axis=-1)


@functools.lru_cache(maxsize=None)
def _weight_lut_np(delta: int, dtype_name: str) -> np.ndarray:
    # Computed in float64 then cast: the LUT is tiny and shared by every
    # voxel, so we do not let LUT rounding contribute to the error budget.
    u = np.arange(delta, dtype=np.float64) / float(delta)
    b0 = (1.0 - u) ** 3 / 6.0
    b1 = (3.0 * u**3 - 6.0 * u**2 + 4.0) / 6.0
    b2 = (-3.0 * u**3 + 3.0 * u**2 + 3.0 * u + 1.0) / 6.0
    b3 = u**3 / 6.0
    return np.stack([b0, b1, b2, b3], axis=-1).astype(dtype_name)


def weight_lut(delta: int, dtype=jnp.float32):
    """``(delta, 4)`` aligned-grid weight LUT: ``W[a, l] = B_l(a / delta)``."""
    return jnp.asarray(_weight_lut_np(int(delta), jnp.dtype(dtype).name))


@functools.lru_cache(maxsize=None)
def _basis_matrix_np(tile: tuple, dtype_name: str) -> np.ndarray:
    dx, dy, dz = tile
    wx = _weight_lut_np(dx, "float64")
    wy = _weight_lut_np(dy, "float64")
    wz = _weight_lut_np(dz, "float64")
    b = np.einsum("al,bm,cn->abclmn", wx, wy, wz)
    return b.reshape(dx * dy * dz, 64).astype(dtype_name)


def basis_matrix(tile, dtype=jnp.float32):
    """``(dx*dy*dz, 64)`` matrix form of the 3-D aligned-grid basis.

    ``B[v, k] = Wx[a, l] * Wy[b, m] * Wz[c, n]`` with voxel offset
    ``v = (a*dy + b)*dz + c`` and control offset ``k = (l*4 + m)*4 + n`` —
    the Kronecker product of the three per-axis ``(delta, 4)`` LUTs, so one
    ``(tile^3, 64) @ (64, C)`` matmul per tile evaluates the whole cell
    (Wu & Zou's matrix representation; the ``mode="matmul"`` hot path).
    Rows sum to 1 (partition of unity per axis, three times).
    """
    tile = tuple(int(d) for d in tile)
    return jnp.asarray(_basis_matrix_np(tile, jnp.dtype(dtype).name))


@functools.lru_cache(maxsize=None)
def _lerp_luts_np(delta: int, dtype_name: str):
    w = _weight_lut_np(delta, "float64")
    b0, b1, b2, b3 = w[:, 0], w[:, 1], w[:, 2], w[:, 3]
    # Pairwise renormalisation (paper §3.3): B0*p0 + B1*p1 ==
    # (B0+B1) * lerp(p0, p1, B1/(B0+B1)).  Partition of unity makes the final
    # combine a lerp too: (B0+B1) + (B2+B3) == 1.
    t0 = b1 / (b0 + b1)
    t1 = b3 / (b2 + b3)
    s = b2 + b3
    return tuple(a.astype(dtype_name) for a in (t0, t1, s))


def lerp_luts(delta: int, dtype=jnp.float32):
    """LUTs for the TTLI lerp form, each of shape ``(delta,)``.

    ``t0[a] = B1/(B0+B1)``, ``t1[a] = B3/(B2+B3)``, ``s[a] = B2+B3`` so that

        sum_l B_l(u_a) * p_l == lerp(lerp(p0,p1,t0), lerp(p2,p3,t1), s)

    which is 3 lerps (6 FMA-class ops) per axis level — the exact regrouping
    of paper App. B (63 lerps = 126 ops per voxel in 3-D).
    """
    t0, t1, s = _lerp_luts_np(int(delta), jnp.dtype(dtype).name)
    return jnp.asarray(t0), jnp.asarray(t1), jnp.asarray(s)


def grid_points_for_tiles(num_tiles) -> tuple:
    """Stored control-grid points per axis for ``num_tiles`` tiles (+3 halo)."""
    return tuple(int(t) + 3 for t in num_tiles)
