"""Non-uniform / non-aligned control grids — the paper's §8 future work.

The paper's implementations require the control grid to be voxel-aligned
and uniformly spaced (integer tile sizes), which makes every per-axis
weight a LUT entry.  The paper notes: "Support for non-uniform grids is
possible with minimal changes (e.g., calculating B-spline basis functions
weights on-the-fly). We leave this support for future work."

This module is that support: arbitrary *fractional* spacing per axis (and
therefore arbitrary real-valued control-point pitch).  Weights are computed
on the fly per voxel (``bspline_basis``), with the same separable structure
as the aligned fast path wherever the problem remains separable — spacing
is per-axis, so the weight tensor factorises into three (len, 4) matrices
even when nothing is integer:

    out[x, y, z] = sum_{l,m,n} Wx[x,l] * Wy[y,m] * Wz[z,n]
                               * phi[ix[x]+l, iy[y]+m, iz[z]+n]

The gather is per-voxel (base indices differ), but each axis's (index,
weight) pair is precomputed once per axis — O(len·4) setup, not O(vox·64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bspline import bspline_basis

__all__ = ["axis_weights", "bsi_nonuniform", "grid_points_for_spacing"]


def grid_points_for_spacing(vol_shape, spacing) -> tuple:
    """Stored control points per axis for real-valued ``spacing``."""
    return tuple(int(jnp.ceil(s / d)) + 3 for s, d in zip(vol_shape, spacing))


def axis_weights(length, delta, dtype=jnp.float32):
    """Per-axis base indices and on-the-fly weights for spacing ``delta``.

    Returns (idx (len,), W (len, 4)) with idx the stored base control point
    (+1 offset convention) and W the four basis values at each coordinate.
    """
    x = jnp.arange(length, dtype=jnp.float32) / jnp.asarray(delta, jnp.float32)
    base = jnp.floor(x)
    u = x - base
    return base.astype(jnp.int32), bspline_basis(u, dtype)


@functools.partial(jax.jit, static_argnames=("vol_shape",))
def bsi_nonuniform(phi, spacing, vol_shape):
    """Dense field from a control grid at arbitrary real spacing.

    Args:
      phi: ``(nx, ny, nz, C)`` stored control grid (+1 offset convention).
      spacing: 3 floats (voxels per control interval, need not be integer).
      vol_shape: output volume shape.

    Returns ``vol_shape + (C,)``.
    """
    X, Y, Z = vol_shape
    ix, wx = axis_weights(X, spacing[0], phi.dtype)
    iy, wy = axis_weights(Y, spacing[1], phi.dtype)
    iz, wz = axis_weights(Z, spacing[2], phi.dtype)

    nx, ny, nz = phi.shape[:3]
    out = jnp.zeros((X, Y, Z, phi.shape[-1]), phi.dtype)
    # separable in weights; gather per (l, m, n) shift — 64 terms like the
    # aligned gather form, but with per-voxel bases.
    for l in range(4):
        gx = jnp.clip(ix + l, 0, nx - 1)
        for m in range(4):
            gy = jnp.clip(iy + m, 0, ny - 1)
            for n in range(4):
                gz = jnp.clip(iz + n, 0, nz - 1)
                g = phi[gx[:, None, None], gy[None, :, None], gz[None, None, :]]
                w = (wx[:, l][:, None, None]
                     * wy[:, m][None, :, None]
                     * wz[:, n][None, None, :])
                out = out + g * w[..., None]
    return out
