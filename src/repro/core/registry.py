"""The shared name->value registry behind the pluggable axes.

``similarity=``, ``transform=`` and ``regularizer=`` are the same API shape:
a small closed set of built-in options addressed by name, factory-built
variants that canonicalise back to their parameters, and a clear
``ValueError`` listing the valid names when a caller typos one.  Before this
module each axis re-implemented that shape by hand (PR 2's similarity
registry was the template); :class:`Registry` extracts it so the three axes
— and any future one (``optimizer=``, a fifth BSI mode's dispatch table) —
behave identically:

* ``register(name, value)`` / ``@register(name)`` — add an entry;
* ``get(name)`` — look one up, raising ``ValueError`` with the sorted valid
  names on a miss;
* ``resolve(obj)`` — the entry-point face: a registered name returns
  ``(name, value)``; a registered *value* canonicalises back to its name
  (so ``similarity=nmi()`` and ``similarity="nmi"`` share every cache);
  unregistered objects either pass through (``passthrough=`` predicate —
  similarity accepts arbitrary loss callables) or raise.

Values can be anything hashable-adjacent the axis needs: similarity stores
loss callables, transform/regularizer store frozen spec dataclasses whose
instances double as ``RegistrationOptions`` cache-key fields.
"""
from __future__ import annotations

__all__ = ["Registry"]


class Registry:
    """A named table of pluggable options with uniform lookup semantics."""

    def __init__(self, kind, *, passthrough=None, hint=None):
        """``kind`` names the axis in error messages (e.g. ``"similarity"``).

        ``passthrough`` — optional predicate: unregistered objects it accepts
        resolve to themselves (key == value) instead of raising.  ``hint`` —
        optional suffix appended to the unknown-name error (e.g. ``"or pass
        a callable"``).
        """
        self.kind = str(kind)
        self._entries: dict = {}
        self._passthrough = passthrough
        self._hint = hint

    def register(self, name, value=None):
        """Register ``value`` under ``name`` (also usable as a decorator)."""
        if value is None:
            return lambda v: self.register(name, v)
        self._entries[str(name)] = value
        return value

    def names(self) -> list:
        """Sorted names of the registered entries."""
        return sorted(self._entries)

    def __contains__(self, name) -> bool:
        return str(name) in self._entries

    def items(self):
        return self._entries.items()

    def _unknown(self, obj):
        hint = f" {self._hint}" if self._hint else ""
        return ValueError(
            f"unknown {self.kind} {obj!r}; choose from {self.names()}{hint}")

    def get(self, name):
        """The value registered under ``name`` (``ValueError`` on a miss)."""
        try:
            return self._entries[str(name)]
        except KeyError:
            raise self._unknown(name) from None

    def resolve(self, obj):
        """Resolve a name-or-value to ``(key, value)``.

        ``key`` is hashable and stable across calls — the registry name
        where one exists (a registered value canonicalises back to its
        name, so the name and value spellings share compiled-runner and
        autotune caches), otherwise the passed-through object itself.
        """
        if isinstance(obj, str):
            return str(obj), self.get(obj)
        for name, value in self._entries.items():
            # identity for unhashable values (callables compare by identity
            # anyway); equality so factory-built frozen specs canonicalise
            # (velocity() == the registered VelocityTransform())
            if value is obj or (type(value) is type(obj) and value == obj):
                return name, value
        if self._passthrough is not None and self._passthrough(obj):
            return obj, obj
        raise self._unknown(obj)
