"""Pluggable regularizers: analytic B-spline bending energy as a layer.

The historical pipeline hardcoded one smoothness term — ``bending_weight *
ffd.bending_energy(phi)``, a second-order *finite-difference* proxy on the
control lattice.  Shah et al. ("A Generalized Framework for Analytic
Regularization of Uniform Cubic B-spline Displacement Fields", PAPERS.md)
show the proxy is unnecessary: because the displacement field is a uniform
cubic B-spline, the true thin-plate bending energy

    E = ∫∫∫ u_xx² + u_yy² + u_zz² + 2(u_xy² + u_xz² + u_yz²) dV

is an **exact separable quadratic form on the control points** — six terms
of the shape ``φᵀ (Gx^{d₁} ⊗ Gy^{d₂} ⊗ Gz^{d₃}) φ`` where each ``G^{d}`` is
the 1-D Gram matrix of d-th basis-function derivatives (a 7-banded matrix,
computed here by exact Gauss-Legendre quadrature of the piecewise-cubic
products).  Applying the operator is three small matmuls per term on the
*coarse grid* — orders of magnitude cheaper than anything touching the
dense field — and, the form being quadratic and symmetric, the gradient is
closed-form: ``∇E = 2 Q φ``, the same separable application again.  The
energy here ships with that analytic gradient as a ``jax.custom_vjp`` (no
autodiff through the quadrature products).

Registry entries (the shared ``core.registry`` shape, like ``similarity=``
and ``transform=``):

``none``     no *analytic* regularizer — the pipeline's historical
             behaviour, where the legacy ``bending_weight`` option still
             applies its finite-difference proxy (default weight 5e-3);
             bit-identical to the pre-regularizer-axis stack.
``bending``  Shah et al.'s exact bending energy, **replacing** the
             finite-difference proxy (the legacy ``bending_weight`` term is
             dropped); the weight is a factory parameter:
             ``bending(weight=1e-3)``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ffd
from repro.core.registry import Registry

__all__ = [
    "REGULARIZERS",
    "BendingRegularizer",
    "NoRegularizer",
    "available_regularizers",
    "bending",
    "bending_energy_fn",
    "bending_gram_matrices",
    "none",
    "regularizer_term",
    "regularizer_token",
    "resolve_regularizer",
]


@dataclasses.dataclass(frozen=True)
class NoRegularizer:
    """No analytic regularizer (the legacy ``bending_weight`` proxy stays)."""

    name = "none"


@dataclasses.dataclass(frozen=True)
class BendingRegularizer:
    """Analytic uniform-cubic-B-spline bending energy at ``weight``."""

    name = "bending"
    weight: float = 1e-3

    def __post_init__(self):
        w = float(self.weight)
        if not w >= 0:
            raise ValueError(
                f"bending weight must be >= 0, got {self.weight!r}")
        object.__setattr__(self, "weight", w)


REGULARIZERS = Registry(
    "regularizer",
    passthrough=lambda o: isinstance(o, (NoRegularizer, BendingRegularizer)))


def none() -> NoRegularizer:
    """The no-analytic-regularizer spec (the default)."""
    return NoRegularizer()


def bending(weight=1e-3) -> BendingRegularizer:
    """An analytic-bending-energy spec with the given weight."""
    return BendingRegularizer(weight=weight)


REGULARIZERS.register("none", NoRegularizer())
REGULARIZERS.register("bending", BendingRegularizer())


def available_regularizers():
    """Sorted names of the registered regularizers."""
    return REGULARIZERS.names()


def resolve_regularizer(regularizer):
    """Resolve a name-or-spec to a frozen regularizer spec instance."""
    _, spec = REGULARIZERS.resolve(regularizer)
    return spec


def regularizer_token(regularizer) -> str:
    """A short string naming the regularizer for cache keys and logs."""
    spec = resolve_regularizer(regularizer)
    if isinstance(spec, BendingRegularizer):
        return f"bending(weight={spec.weight:g})"
    return "none"


# --- the analytic quadratic form --------------------------------------------
#
# Basis convention (matching core.interpolate): at position s in tile-index
# coordinates, u(s) = Σ_i φ_i β(s - i + 1) with β the cardinal cubic
# B-spline (support (-2, 2)); a grid of n stored points spans T = n - 3
# tiles, i.e. the domain s ∈ [0, T].


def _beta(x, d):
    """The cardinal cubic B-spline (d-th derivative), vectorised numpy."""
    a = np.abs(x)
    s = np.sign(x)
    inner, outer = a <= 1.0, (a > 1.0) & (a < 2.0)
    out = np.zeros_like(x)
    if d == 0:
        out[inner] = 2.0 / 3.0 - a[inner] ** 2 + a[inner] ** 3 / 2.0
        out[outer] = (2.0 - a[outer]) ** 3 / 6.0
    elif d == 1:
        out[inner] = s[inner] * (-2.0 * a[inner] + 1.5 * a[inner] ** 2)
        out[outer] = s[outer] * (-0.5 * (2.0 - a[outer]) ** 2)
    elif d == 2:
        out[inner] = -2.0 + 3.0 * a[inner]
        out[outer] = 2.0 - a[outer]
    else:
        raise ValueError(f"cubic B-spline derivative order {d} not needed")
    return out


@functools.lru_cache(maxsize=None)
def bending_gram_matrices(n):
    """The 1-D Gram matrices ``(G⁰, G¹, G²)`` for an ``n``-point axis.

    ``G^d[i, j] = ∫₀ᵀ β^{(d)}(s-i+1) β^{(d)}(s-j+1) ds`` with ``T = n - 3``
    tiles — **exact**: the integrand is piecewise polynomial of degree ≤ 6,
    so 4-point Gauss-Legendre per unit knot interval integrates it without
    error.  7-banded, symmetric; returned as fp32 *numpy* arrays — the
    function is lru-cached and may first run inside a jit trace, where a jnp
    conversion would cache that trace's tracer (constants embed per-trace at
    the einsum instead).
    """
    n = int(n)
    tiles = n - 3
    if tiles < 1:
        raise ValueError(f"grid axis of {n} points spans no tiles")
    pts, wts = np.polynomial.legendre.leggauss(4)
    t = (pts + 1.0) / 2.0          # quadrature nodes on one knot interval
    w = wts / 2.0
    grams = [np.zeros((n, n)) for _ in range(3)]
    # per interval [c, c+1] only basis functions i = c..c+3 are non-zero;
    # N_{c+l}(c + t) = β(t + 1 - l)
    vals = [np.stack([_beta(t + 1.0 - l, d) for l in range(4)])
            for d in range(3)]     # (4, q) per derivative order
    for c in range(tiles):
        for d in range(3):
            block = np.einsum("iq,jq,q->ij", vals[d], vals[d], w)
            grams[d][c:c + 4, c:c + 4] += block
    return tuple(g.astype(np.float32) for g in grams)


def _apply_separable(phi, gx, gy, gz):
    """``(G_x ⊗ G_y ⊗ G_z) φ`` on a ``(nx, ny, nz, C)`` control grid."""
    out = jnp.einsum("ia,abcd->ibcd", gx, phi)
    out = jnp.einsum("jb,ibcd->ijcd", gy, out)
    return jnp.einsum("kc,ijcd->ijkd", gz, out)


# The six second-derivative terms of the bending integrand with their
# multiplicities: (dx_order, dy_order, dz_order, multiplicity).
_BENDING_TERMS = ((2, 0, 0, 1.0), (0, 2, 0, 1.0), (0, 0, 2, 1.0),
                  (1, 1, 0, 2.0), (1, 0, 1, 2.0), (0, 1, 1, 2.0))


@functools.lru_cache(maxsize=None)
def bending_energy_fn(grid_shape, tile):
    """Build ``phi -> mean bending-energy density`` for one grid geometry.

    The returned callable evaluates the exact integral (normalised by the
    spline domain's volume in voxels, so weights stay comparable across
    pyramid levels) and carries the closed-form gradient ``2 Q φ`` as a
    ``jax.custom_vjp`` — the backward is one more separable application, not
    autodiff through the quadrature form.  Cached per ``(grid_shape, tile)``
    so every pyramid level compiles its operator once.
    """
    grid_shape = tuple(int(g) for g in grid_shape)
    tile = tuple(int(t) for t in tile)
    grams = [bending_gram_matrices(n) for n in grid_shape]
    domain = float(np.prod([(n - 3) * h for n, h in zip(grid_shape, tile)]))
    # per-term scale: each axis contributes h^(1-2d) (change of variables
    # s = x/h), divided by the domain volume for a mean density
    scales = [m * float(np.prod([h ** (1 - 2 * d)
                                 for h, d in zip(tile, (d1, d2, d3))]))
              / domain
              for d1, d2, d3, m in _BENDING_TERMS]

    def apply_q(p):
        """``Q φ`` — the symmetric operator of the quadratic form."""
        out = jnp.zeros_like(p)
        for (d1, d2, d3, _), s in zip(_BENDING_TERMS, scales):
            out = out + s * _apply_separable(
                p, grams[0][d1], grams[1][d2], grams[2][d3])
        return out

    def energy_reference(p):
        """``φᵀ Q φ`` with no custom VJP (autodiff target for tests)."""
        p = jnp.asarray(p, jnp.float32)
        return jnp.sum(p * apply_q(p))

    @jax.custom_vjp
    def energy(p):
        return energy_reference(p)

    def fwd(p):
        p = jnp.asarray(p, jnp.float32)
        qp = apply_q(p)
        return jnp.sum(p * qp), qp

    def bwd(qp, g):
        return (g * 2.0 * qp,)   # ∇(φᵀQφ) = 2Qφ: Q symmetric by construction

    energy.defvjp(fwd, bwd)
    energy.reference = energy_reference
    return energy


def regularizer_term(regularizer, *, grid_shape, tile, bending_weight):
    """The ``phi -> scalar`` regularisation term for one pyramid level.

    ``none`` reproduces the historical objective exactly — the legacy
    ``bending_weight``-scaled finite-difference proxy
    (``ffd.bending_energy``), bit-identical to the pre-regularizer-axis
    pipeline.  ``bending`` **replaces** that proxy with the analytic energy
    at the spec's own weight (``bending_weight`` is ignored — the two terms
    regularise the same thing and must not stack).
    """
    spec = resolve_regularizer(regularizer)
    if isinstance(spec, BendingRegularizer):
        energy = bending_energy_fn(tuple(grid_shape), tuple(tile))
        weight = spec.weight

        def term(p):
            return weight * energy(p)

        return term

    bw = float(bending_weight)

    def legacy(p):
        return bw * ffd.bending_energy(p)

    return legacy
