"""Batched serving driver: prefill + decode with a quantizable KV cache.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.training.steps import make_decode_step, make_prefill_step

__all__ = ["generate", "make_generate_steps", "main"]


def make_generate_steps(cfg, max_len):
    """The jitted (prefill, decode) pair ``generate`` runs on.

    Build once and pass as ``generate(..., steps=...)`` when timing: each
    ``generate`` call otherwise creates fresh jitted closures, so
    back-to-back calls re-trace and a naive timer charges every call the
    compile cost.
    """
    return (jax.jit(make_prefill_step(cfg, max_len=max_len)),
            jax.jit(make_decode_step(cfg)))


def generate(cfg, params, prompts, max_len, gen_steps, *, greedy=True, seed=0,
             steps=None):
    """prompts: (B, P) int32. Returns (B, gen_steps) generated tokens."""
    B, P = prompts.shape
    prefill, decode = (make_generate_steps(cfg, max_len) if steps is None
                       else steps)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "encdec":
        batch["frame_embeddings"] = jnp.zeros(
            (B, max(P // cfg.encoder_seq_divisor, 1), cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeddings"] = jnp.zeros(
            (B, cfg.img_tokens, cfg.d_model), jnp.float32)
    logits, cache = prefill(params, batch)
    rng = jax.random.PRNGKey(seed)
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for i in range(gen_steps):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        if greedy:
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits[:, -1])[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1), cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kv-dtype", default=None, choices=[None, "bfloat16", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.kv_dtype:
        cfg = cfg.__class__(**{**cfg.__dict__, "kv_cache_dtype": args.kv_dtype})
    params = M.init_model(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    max_len = args.prompt_len + args.gen + 1

    steps = make_generate_steps(cfg, max_len)
    t0 = time.perf_counter()
    toks, cache = generate(cfg, params, prompts, max_len, args.gen,
                           steps=steps)
    jax.block_until_ready(toks)
    warm = time.perf_counter() - t0  # first call pays trace + compile
    t0 = time.perf_counter()
    toks, cache = generate(cfg, params, prompts, max_len, args.gen,
                           steps=steps)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0  # steady-state serving path
    n = args.batch * args.gen
    print(f"arch={cfg.name} kv={cfg.kv_cache_dtype} generated {n} tokens "
          f"in {dt:.2f}s ({n/dt:.1f} tok/s warm; first call {warm:.2f}s "
          "incl. compile)")
    print("sample:", np.asarray(toks[0, :16]))


if __name__ == "__main__":
    main()
