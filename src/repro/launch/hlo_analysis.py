"""Static analysis of compiled (post-SPMD) HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE, so any
scanned program (all of ours: layers, attention chunks, loss chunks) is
undercounted by the trip count.  This module re-derives per-device

  * dot FLOPs            (2 x prod(out dims) x prod(contracting dims))
  * HBM traffic bytes    (operand + output bytes of top-level ops; fusion
                          internals excluded — a fusion reads its inputs and
                          writes its output once)
  * collective bytes     (output bytes per collective kind)

by walking the call graph from ENTRY and scaling every ``while`` body by its
``known_trip_count`` backend config.  Validated against an unrolled oracle in
``tests/test_hlo_analysis.py``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "buffer_shapes", "materializes_shape", "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
# tuple types may contain /*index=N*/ comments (hence [^()] not [^=])
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\]{},]+))\s+"
    r"([\w\-]+)\("
)
_CALL_ATTRS = ("calls", "to_apply", "body", "condition")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems(type_str):
    """[(dtype, n_elems), ...] across tuple elements."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dtype, n))
    return out


def _shape_bytes(type_str):
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_elems(type_str))


def buffer_shapes(text):
    """Every array shape named anywhere in an HLO module.

    Returns a set of ``(dtype, dims)`` tuples covering op outputs, parameters
    and fusion internals alike.  The coarseness is the point: used with
    :func:`materializes_shape` it supports assertions of the form "this
    lowering never even *names* a dense-field-sized buffer" — stronger than
    checking top-level (HBM) buffers only, since a shape absent from the
    whole module text cannot be materialised by any schedule of it.
    """
    out = set()
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.add((dtype, shape))
    return out


def materializes_shape(text, dims) -> bool:
    """True if any buffer in the HLO has extents ``dims``, up to axis order.

    Axis order is ignored because XLA freely transposes logical layouts — a
    ``(3, X, Y, Z)`` channel-first copy of an ``(X, Y, Z, 3)`` displacement
    field is still the dense field in HBM.
    """
    want = sorted(int(d) for d in dims)
    return any(sorted(shape) == want for _, shape in buffer_shapes(text))


def _dims_of(type_str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class _Op:
    var: str
    type_str: str
    opcode: str
    line: str
    operands: list
    calls: list
    trip: int = 1
    is_root: bool = False


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)
    while_trips: list = field(default_factory=list)

    @property
    def total_collective_bytes(self):
        return sum(self.collective_bytes.values())


_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_computations(text):
    comps = {}
    cur_name, cur_ops, symtab = None, None, None
    entry = None
    for line in text.splitlines():
        if cur_name is None:
            if line.endswith("{"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur_name = m.group(1)
                    if line.strip().startswith("ENTRY"):
                        entry = cur_name
                    cur_ops = []
                    symtab = {}
                    # parameter types from the header signature
                    for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\]{},]+))",
                                          m.group(2)):
                        symtab[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            comps[cur_name] = (cur_ops, symtab)
            cur_name = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        var, type_str, opcode = m.group(1), m.group(2), m.group(3)
        symtab[var] = type_str
        # operands: names inside the first (...) after the opcode
        paren = line[line.index(opcode + "(") + len(opcode):]
        depth = 0
        arglist = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                arglist += ch
        operands = _OPERAND_RE.findall(arglist)
        calls = []
        for attr in _CALL_ATTRS:
            for cm in re.finditer(attr + r"=%([\w.\-]+)", line):
                calls.append((attr, cm.group(1)))
        bm = re.search(r"branch_computations=\{([^}]*)\}", line)
        if bm:
            for name in _OPERAND_RE.findall(bm.group(1)):
                calls.append(("branch", name))
        bc = _TRIP_RE.search(line)
        trip = int(bc.group(1)) if bc else 1
        cur_ops.append(_Op(var, type_str, opcode, line, operands, calls, trip,
                           "ROOT " in line[:12]))
    return comps, entry


def _dot_flops(op: _Op, symtab):
    out_elems = 1
    for d in _dims_of(op.type_str):
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if m and op.operands:
        lhs_type = symtab.get(op.operands[0], "")
        lhs_dims = _dims_of(lhs_type)
        if m.group(1):
            for i in m.group(1).split(","):
                i = int(i)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(op: _Op, symtab):
    # output elems x (2 x kernel elems / output-channels) — good enough for
    # the rare conv in this codebase (none in the dry-run graphs today).
    out_elems = 1
    for d in _dims_of(op.type_str):
        out_elems *= d
    if len(op.operands) >= 2:
        k_elems = 1
        for d in _dims_of(symtab.get(op.operands[1], "")):
            k_elems *= d
        return 2.0 * out_elems * k_elems
    return 0.0


def analyze_hlo(text: str) -> HloStats:
    comps, entry = _parse_computations(text)
    stats = HloStats(
        collective_bytes={k: 0.0 for k in _COLLECTIVES},
        collective_counts={k: 0 for k in _COLLECTIVES},
    )
    flops_memo = {}

    def comp_flops(name):
        """dot/conv FLOPs of a computation including nested calls (memoised,
        while-scaling applied at the call site)."""
        if name in flops_memo:
            return flops_memo[name]
        ops, symtab = comps.get(name, ([], {}))
        total = 0.0
        for op in ops:
            if op.opcode == "dot":
                total += _dot_flops(op, symtab)
            elif op.opcode == "convolution":
                total += _conv_flops(op, symtab)
            for attr, callee in op.calls:
                if attr == "condition":
                    continue
                mult = op.trip if (op.opcode == "while" and attr == "body") else 1
                total += mult * comp_flops(callee)
        flops_memo[name] = total
        return total

    visited_bytes = {}

    def _sliced_operand_bytes(callee, i, fallback):
        """If fusion parameter ``i`` is only consumed by slice/gather/update
        ops, the real HBM traffic is the slice/update size, not the whole
        operand (the layer-scan weight-slice / carry-update patterns)."""
        ops, sym = comps.get(callee, ([], {}))
        pvar = None
        for op in ops:
            if op.opcode == "parameter" and f"parameter({i})" in op.line:
                pvar = op.var
                break
        if pvar is None:
            return fallback
        consumers = [op for op in ops if pvar in op.operands]
        slicey = ("dynamic-slice", "slice", "gather", "dynamic-update-slice")
        if consumers and all(op.opcode in slicey for op in consumers):
            total = 0.0
            for op in consumers:
                if op.opcode == "dynamic-update-slice":
                    # in-place update: traffic = update operand size
                    if len(op.operands) > 1 and op.operands[0] == pvar:
                        total += _shape_bytes(sym.get(op.operands[1], ""))
                    else:  # param is the update itself
                        total += _shape_bytes(sym.get(pvar, ""))
                else:
                    total += _shape_bytes(op.type_str)
            return total
        return fallback

    def _fusion_out_bytes(callee, fallback):
        """A fusion rooted in dynamic-update-slice writes only the update
        (the target buffer is aliased in place)."""
        ops, sym = comps.get(callee, ([], {}))
        for op in ops:
            if not op.is_root:
                continue
            cur = op
            # look through a root bitcast to the DUS
            for _ in range(3):
                if cur.opcode == "dynamic-update-slice":
                    if len(cur.operands) > 1:
                        return _shape_bytes(sym.get(cur.operands[1], ""))
                    return fallback
                if cur.opcode == "bitcast" and cur.operands:
                    nxt = next((o2 for o2 in ops if o2.var == cur.operands[0]),
                               None)
                    if nxt is None:
                        break
                    cur = nxt
                else:
                    break
        return fallback

    def op_bytes(op, symtab):
        out_b = _shape_bytes(op.type_str)
        if op.opcode in ("dynamic-slice", "slice", "gather"):
            return 2.0 * out_b              # read slice + write output
        if op.opcode == "dynamic-update-slice":
            upd = _shape_bytes(symtab.get(op.operands[1], "")) if len(op.operands) > 1 else out_b
            return 2.0 * upd                # read update + write in place
        if op.opcode == "scatter":
            upd = _shape_bytes(symtab.get(op.operands[2], "")) if len(op.operands) > 2 else out_b
            return 3.0 * upd                # read update+target slice, write
        if op.opcode == "broadcast":
            return out_b
        if op.opcode == "fusion":
            callee = next((c for a, c in op.calls if a == "calls"), None)
            b = _fusion_out_bytes(callee, out_b)
            for i, o in enumerate(op.operands):
                ob = _shape_bytes(symtab.get(o, ""))
                if callee is not None and ob > out_b:
                    ob = _sliced_operand_bytes(callee, i, ob)
                b += ob
            return b
        b = out_b
        for o in op.operands:
            b += _shape_bytes(symtab.get(o, ""))
        return b

    def comp_bytes(name):
        if name in visited_bytes:
            return visited_bytes[name]
        ops, symtab = comps.get(name, ([], {}))
        total = 0.0
        for op in ops:
            if op.opcode == "while":
                for attr, callee in op.calls:
                    if attr == "body":
                        total += op.trip * comp_bytes(callee)
                continue
            if op.opcode in ("call", "conditional"):
                for attr, callee in op.calls:
                    if attr != "condition":
                        total += comp_bytes(callee)
                continue
            if op.opcode in _SKIP_BYTES:
                continue
            total += op_bytes(op, symtab)
        visited_bytes[name] = total
        return total

    def comp_collectives(name, mult):
        ops, symtab = comps.get(name, ([], {}))
        for op in ops:
            kind = op.opcode.removesuffix("-start")
            if kind in _COLLECTIVES and not op.opcode.endswith("-done"):
                stats.collective_bytes[kind] += mult * _shape_bytes(op.type_str)
                stats.collective_counts[kind] += mult
            for attr, callee in op.calls:
                if attr == "condition":
                    continue
                m2 = op.trip if (op.opcode == "while" and attr == "body") else 1
                comp_collectives(callee, mult * m2)
            if op.opcode == "while":
                stats.while_trips.append(op.trip)

    if entry:
        stats.flops = comp_flops(entry)
        stats.bytes_accessed = comp_bytes(entry)
        comp_collectives(entry, 1)
    return stats
