# The dry-run needs 512 placeholder devices; jax locks the device count on
# first init, so these two lines MUST precede every other import.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: ``jax.jit(step).lower(...).compile()`` must succeed on the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh, with full-size parameters /
caches as ShapeDtypeStructs (nothing is allocated).  Records
``memory_analysis`` (fits?), ``cost_analysis`` (FLOPs/bytes) and the
collective-bytes HLO parse for §Roofline into results/dryrun/*.json.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import (
    SHAPES, cell_supported, get_config, input_specs,
)
from repro.distributed.sharding import (
    DECODE_RULES, LONG_CONTEXT_RULES, TRAIN_RULES, partition_specs,
    sanitize_specs, shardings_for,
)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, roofline_terms
from repro.models import model as M
from repro.optim.optimizer import OptConfig
from repro.training.steps import abstract_train_state, make_decode_step, \
    make_prefill_step, make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

LM_ARCHS = [
    "qwen1.5-32b", "gemma3-1b", "gemma2-2b", "internlm2-1.8b",
    "qwen2-moe-a2.7b", "arctic-480b", "xlstm-1.3b", "hymba-1.5b",
    "whisper-base", "llama-3.2-vision-90b",
]


def _rules_for(cfg, shape, mesh):
    if shape.kind == "train":
        return TRAIN_RULES(mesh.axis_names)
    if shape.name == "long_500k":
        return LONG_CONTEXT_RULES(mesh.axis_names)
    return DECODE_RULES(mesh.axis_names)


def _arch_overrides(cfg, shape):
    """Per-cell production settings (documented in EXPERIMENTS.md §Dry-run)."""
    over = {}
    if shape.kind == "decode" and shape.global_batch * shape.seq_len >= 2**22:
        over["kv_cache_dtype"] = "int8"   # 32k x 128 caches need int8 (DESIGN §5)
    if cfg.name == "arctic-480b" and shape.kind == "train":
        over["opt_moment_dtype"] = "bfloat16"  # fit 480B optimizer state
    return over


def lower_cell(arch, shape_name, multi_pod, ocfg=None):
    """Lower + compile one cell; returns the record dict."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped", "reason": reason}

    over = _arch_overrides(cfg, shape)
    moment_dtype = over.pop("opt_moment_dtype", "float32")
    if over:
        cfg = cfg.__class__(**{**cfg.__dict__, **over})
    ocfg = ocfg or OptConfig(moment_dtype=moment_dtype)

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = _rules_for(cfg, shape, mesh)
    schema = M.model_schema(cfg)
    abstract_p = M.abstract_model(cfg, dtype=jnp.float32)
    pspecs = sanitize_specs(abstract_p, partition_specs(schema, rules), mesh)
    specs = input_specs(cfg, shape)
    batch_specs = sanitize_specs(
        specs, M.batch_partition_specs(cfg, shape.kind, rules), mesh)

    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            state = abstract_train_state(cfg, ocfg)
            state_specs = {
                "params": pspecs,
                "opt": {"m": pspecs, "v": pspecs, "step": PartitionSpec()},
            }
            step = make_train_step(cfg, ocfg, rules)
            in_sh = (shardings_for(state_specs, mesh),
                     shardings_for(batch_specs, mesh))
            lowered = jax.jit(
                step, in_shardings=in_sh,
                out_shardings=(in_sh[0], None),
            ).lower(state, specs)
        elif shape.kind == "prefill":
            params = abstract_p
            step = make_prefill_step(cfg, rules, max_len=shape.seq_len)
            cache_abs = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
            cache_specs = sanitize_specs(
                cache_abs, M.cache_partition_specs(cfg, rules), mesh)
            in_sh = (shardings_for(pspecs, mesh), shardings_for(batch_specs, mesh))
            lowered = jax.jit(
                step, in_shardings=in_sh,
                out_shardings=(None, shardings_for(cache_specs, mesh)),
            ).lower(params, specs)
        else:  # decode
            params = abstract_p
            cache = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
            cache_specs = sanitize_specs(
                cache, M.cache_partition_specs(cfg, rules), mesh)
            step = make_decode_step(cfg, rules)
            in_sh = (
                shardings_for(pspecs, mesh),
                shardings_for(cache_specs, mesh),
                NamedSharding(mesh, M.batch_partition_specs(cfg, "decode", rules)["tokens"]),
            )
            lowered = jax.jit(
                step, in_shardings=in_sh,
                out_shardings=(None, in_sh[1]),
            ).lower(params, cache, specs["tokens"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # Trip-count-corrected static analysis (XLA:CPU cost_analysis counts
    # while bodies once — see launch/hlo_analysis.py).
    hlo = analyze_hlo(compiled.as_text())
    n_chips = 512 if multi_pod else 256
    mf = model_flops(cfg, shape)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "status": "ok",
        "chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": hlo.flops,
        "bytes_per_device": hlo.bytes_accessed,
        "flops_per_device_loop_once": cost.get("flops", 0.0) if cost else None,
        "collectives": {
            "per_kind_bytes": hlo.collective_bytes,
            "counts": hlo.collective_counts,
            "total_bytes": hlo.total_collective_bytes,
        },
        "memory_analysis": _mem_dict(mem),
        "kv_cache_dtype": cfg.kv_cache_dtype,
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / hlo.flops if hlo.flops else None,
        "roofline": roofline_terms(
            flops_per_device=hlo.flops,
            bytes_per_device=hlo.bytes_accessed,
            collective_bytes_per_device=hlo.total_collective_bytes,
        ),
    }
    return rec


def _mem_dict(mem):
    if mem is None:
        return None
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = getattr(mem, attr)
    return out or str(mem)


def run_cell(arch, shape_name, mesh_name, force=False):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{arch}__{shape_name}__{mesh_name}.json"
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        if rec.get("status") in ("ok", "skipped"):
            print(f"[cached] {path.name}: {rec['status']}")
            return rec
    try:
        rec = lower_cell(arch, shape_name, mesh_name == "multipod")
    except Exception as e:  # a failure here is a bug in the system
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    path.write_text(json.dumps(rec, indent=1, default=str))
    status = rec["status"]
    extra = ""
    if status == "ok":
        extra = (f" compile={rec['compile_s']}s flops/dev={rec['flops_per_device']:.3g}"
                 f" coll={rec['collectives']['total_bytes']:.3g}B")
    print(f"[{status}] {path.name}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for mesh_name in meshes:
            for arch in LM_ARCHS:
                for shape_name in SHAPES:
                    run_cell(arch, shape_name, mesh_name, args.force)
    else:
        assert args.arch and args.shape
        for mesh_name in meshes:
            run_cell(args.arch, args.shape, mesh_name, args.force)


if __name__ == "__main__":
    main()
