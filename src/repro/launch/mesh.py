"""Production meshes.

A function, not a module-level constant: importing this module never touches
jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import so 512 placeholder host devices exist; real deployments get real TPUs.
"""
from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip pod ("data", "model"); 2 pods adds a "pod" DP axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)}. For the "
            "dry-run set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py does this)."
        )
    return jax.make_mesh(shape, axes, devices=devs[:n])
