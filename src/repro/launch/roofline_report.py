"""Render the §Dry-run and §Roofline tables from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.roofline_report [--mesh pod]
"""
from __future__ import annotations

import argparse
import json

from repro.launch.dryrun import RESULTS

ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(mesh="pod", arch_filter=None):
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if arch_filter and not r["arch"].startswith(arch_filter):
            continue
        recs.append(r)
    recs.sort(key=lambda r: (r["arch"], ORDER.get(r.get("shape"), 9),
                             r.get("mode", "")))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(mesh):
    rows = ["| arch | shape | status | compile_s | args/dev | temps/dev | "
            "flops/dev | coll bytes/dev | notes |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load(mesh):
        if r["arch"] == "bsi_paper":
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | - | - | - |"
                        f" - | - | {r['reason'][:60]} |")
            continue
        ma = r.get("memory_analysis") or {}
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} |"
            f" {r.get('compile_s', '-')} |"
            f" {fmt_bytes(ma.get('argument_size_in_bytes'))} |"
            f" {fmt_bytes(ma.get('temp_size_in_bytes'))} |"
            f" {r['flops_per_device']:.3g} |"
            f" {fmt_bytes(r['collectives']['total_bytes'])} |"
            f" kv={r.get('kv_cache_dtype','-')} |"
        )
    return "\n".join(rows)


def roofline_table(mesh):
    rows = ["| arch | shape | compute_s | memory_s | collective_s | dominant |"
            " roofline_frac | useful_flops | one-line diagnosis |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load(mesh):
        if r["status"] != "ok" or r["arch"] == "bsi_paper":
            continue
        rf = r["roofline"]
        uf = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} |"
            f" {rf['compute_s']:.4g} | {rf['memory_s']:.4g} |"
            f" {rf['collective_s']:.4g} | {rf['dominant'].replace('_s','')} |"
            f" {rf['roofline_fraction']:.2f} |"
            f" {uf:.2f} |" if uf is not None else " - |")
        rows[-1] += f" {_diagnose(r)} |"
    return "\n".join(rows)


def bsi_table(mesh):
    rows = ["| volume | mode | compute_s | memory_s | collective_s |"
            " dominant | useful_flops |",
            "|---|---|---|---|---|---|---|"]
    for r in load(mesh, arch_filter="bsi_paper"):
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        uf = r.get("useful_flops_ratio") or 0.0
        rows.append(
            f"| {r['workload']} | {r['mode']} | {rf['compute_s']:.3g} |"
            f" {rf['memory_s']:.3g} | {rf['collective_s']:.3g} |"
            f" {rf['dominant'].replace('_s','')} |"
            f" {uf:.2f} |")
    return "\n".join(rows)


def _diagnose(r):
    rf = r["roofline"]
    dom = rf["dominant"]
    if dom == "memory_s":
        return "HBM-bound: shrink saved activations / cache reads"
    if dom == "collective_s":
        kinds = r["collectives"]["per_kind_bytes"]
        top = max(kinds, key=kinds.get)
        return f"ICI-bound: {top} dominates ({fmt_bytes(kinds[top])})"
    return "compute-bound: good — push MXU utilisation"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    args = ap.parse_args()
    print(f"## Dry-run ({args.mesh})\n")
    print(dryrun_table(args.mesh))
    print(f"\n## Roofline ({args.mesh})\n")
    print(roofline_table(args.mesh))
    print(f"\n## BSI workloads ({args.mesh})\n")
    print(bsi_table(args.mesh))


if __name__ == "__main__":
    main()
