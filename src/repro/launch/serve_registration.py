"""Registration-serving launcher: a Poisson load generator over engine.serve.

Plays an open-loop Poisson stream of mixed-difficulty registration requests
against a :class:`repro.engine.serve.RegistrationScheduler` and reports the
serving numbers that matter for capacity planning: p50/p99 request latency,
sustained pairs/sec, lane-recycling rate, and the compile count (which
should equal ``levels x distinct shapes`` no matter how long the run is).

    python -m repro.launch.serve_registration [--rate 4.0] [--n 32]
    python -m repro.launch.serve_registration --smoke
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m repro.launch.serve_registration --smoke --mesh

``--smoke`` is the CI serving job: 8 mixed pairs (two volume shapes, easy
and hard difficulty) pushed through the queue as fast as the scheduler
accepts them, asserting every request completes and that shape bucketing
held the compile count down.  ``--mesh`` shards the lane arrays over every
local device (fake CPU devices via ``XLA_FLAGS`` above).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def mixed_pairs(n, shapes, hard_every=3, seed=0):
    """Alternating-shape, mixed-difficulty pairs — the serving worst case.

    Easy pairs plateau in a few Adam steps; every ``hard_every``-th needs
    the full budget.  The contrast is what exercises lane recycling, and
    the shape alternation is what exercises bucketing.
    """
    rng = np.random.default_rng(seed)
    waves = {}
    out = []
    for i in range(n):
        shape = shapes[i % len(shapes)]
        if shape not in waves:
            x, y, z = np.meshgrid(
                *[np.linspace(0, np.pi, s) for s in shape], indexing="ij")
            waves[shape] = (np.sin(x) * np.sin(y) * np.sin(z)).astype(
                np.float32)
        f = rng.normal(size=shape).astype(np.float32)
        if hard_every and i % hard_every == 0:
            m = np.roll(f, 3, axis=0) + 2.5 * waves[shape]
            m = m + 0.3 * rng.normal(size=shape).astype(np.float32)
        else:
            m = f + 0.02 * waves[shape]
        out.append((f, m.astype(np.float32)))
    return out


def play(sched, pairs, arrivals, *, timeout=None):
    """Submit ``pairs`` at ``arrivals`` (seconds) and drive to completion."""
    handles, latencies = {}, {}
    start = time.perf_counter()
    submitted = 0
    n = len(pairs)
    while len(latencies) < n:
        now = time.perf_counter() - start
        while submitted < n and arrivals[submitted] <= now:
            f, m = pairs[submitted]
            handles[submitted] = sched.submit(f, m, timeout=timeout)
            submitted += 1
        if sched.pending:
            sched.step()
        elif submitted < n:
            time.sleep(max(arrivals[submitted] - now, 0.0) + 1e-4)
        end = time.perf_counter() - start
        for i, h in handles.items():
            if h.done and i not in latencies:
                latencies[i] = end - arrivals[i]
    return handles, latencies, time.perf_counter() - start


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--shape", type=int, nargs=3, default=(28, 24, 20))
    ap.add_argument("--n", type=int, default=32,
                    help="requests in the stream")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (requests/sec); 0 = closed "
                         "loop, submit as fast as admission allows")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=3)
    ap.add_argument("--iters", type=int, default=32)
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-request deadline in seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", action="store_true",
                    help="shard lane arrays over all local devices (fake a "
                         "pod on CPU: XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 8 mixed pairs over two shapes, assert "
                         "all complete and compiles == levels x shapes")
    args = ap.parse_args(argv)

    from repro.core.options import RegistrationOptions
    from repro.engine.convergence import ConvergenceConfig
    from repro.engine.serve import RegistrationScheduler

    options = RegistrationOptions(
        tile=(6, 6, 6), levels=2, iters=args.iters, lr=0.1,
        mode="separable", impl="jnp", grad_impl="xla",
        stop=ConvergenceConfig(tol=2e-3, patience=3))
    mesh = None
    lanes = args.lanes
    if args.mesh:
        import jax

        from repro.engine.shard import (batch_multiple,
                                        make_registration_mesh)

        mesh = make_registration_mesh()
        mult = batch_multiple(mesh)
        lanes = max(lanes, mult) // mult * mult  # round to an even split
        print(f"mesh: lane arrays sharded over {len(jax.devices())} "
              f"device(s), lanes={lanes}")

    shape = tuple(args.shape)
    if args.smoke:
        n = 8
        shapes = [shape, tuple(max(s - 4, 8) for s in shape)]
    else:
        n = args.n
        shapes = [shape]
    pairs = mixed_pairs(n, shapes, seed=args.seed)

    sched = RegistrationScheduler(options, lanes=lanes, chunk=args.chunk,
                                  max_queue=max(2 * n, 16), mesh=mesh)
    # warm the compiled programs outside the timed stream (one per
    # shape x level — the whole point of shape bucketing)
    for shape_ in shapes:
        f = np.zeros(shape_, np.float32)
        sched.submit(f, f)
    sched.run_until_idle()
    warm_compiles = sched.stats.compiles

    if args.rate > 0:
        rng = np.random.default_rng(args.seed + 1)
        arrivals = np.concatenate(
            [[0.0], rng.exponential(1.0 / args.rate, n - 1)]).cumsum()
    else:
        arrivals = np.zeros(n)
    handles, latencies, makespan = play(sched, pairs, arrivals,
                                        timeout=args.timeout)

    stats = sched.stats
    lat = np.asarray(sorted(latencies.values()))
    completed = sum(1 for h in handles.values() if h._error is None)
    print(f"{completed}/{n} completed in {makespan:.2f}s "
          f"({completed / makespan:.2f} pairs/s sustained)")
    print(f"latency p50 {np.percentile(lat, 50):.3f}s  "
          f"p99 {np.percentile(lat, 99):.3f}s")
    print(f"recycled lanes: {stats.recycled}; chunks: {stats.chunks}; "
          f"buckets: {stats.buckets}; compiles: {stats.compiles} "
          f"({warm_compiles} at warm-up)")
    if stats.timed_out:
        print(f"timed out: {stats.timed_out}")

    if args.smoke:
        assert completed == n, f"smoke: only {completed}/{n} completed"
        expect = options.levels * len(shapes)
        assert stats.compiles == expect, (
            f"smoke: {stats.compiles} stage compiles, expected {expect} "
            f"(levels x shapes) — shape bucketing regressed")
        print("smoke OK")


if __name__ == "__main__":
    main()
