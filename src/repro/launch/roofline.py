"""Roofline model: three terms from the compiled dry-run artifact.

TPU v5e hardware constants (per chip):
  peak bf16 compute  197 TFLOP/s
  HBM bandwidth      819 GB/s
  ICI per link       ~50 GB/s

  compute_term_s    = FLOPs/device / peak
  memory_term_s     = bytes/device / HBM_bw
  collective_term_s = collective bytes/device / link_bw

``cost_analysis`` on the SPMD-partitioned executable reports *per-device*
FLOPs/bytes.  Collective bytes are not in cost_analysis: we parse the
post-optimization HLO and sum operand sizes of every collective op.
"""
from __future__ import annotations

import re

__all__ = [
    "PEAK_FLOPS", "HBM_BW", "LINK_BW",
    "collective_bytes_from_hlo", "roofline_terms", "model_flops",
]

PEAK_FLOPS = 197e12   # bf16 FLOP/s per chip
HBM_BW = 819e9        # B/s per chip
LINK_BW = 50e9        # B/s per ICI link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. "  %ag = bf16[16,4096,512]{2,1,0} all-gather(...)" or tuple-typed ops
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+("
    + "|".join(_COLLECTIVES) + r")[\s(]"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op (per-device program)."""
    per_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        per_kind[kind] += b
        counts[kind] += 1
    return {
        "per_kind_bytes": per_kind,
        "counts": counts,
        "total_bytes": sum(per_kind.values()),
    }


def roofline_terms(flops_per_device, bytes_per_device,
                   collective_bytes_per_device):
    compute_s = (flops_per_device or 0.0) / PEAK_FLOPS
    memory_s = (bytes_per_device or 0.0) / HBM_BW
    collective_s = (collective_bytes_per_device or 0.0) / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dom,
        # fraction of ideal (bound-only) time if overlap were perfect
        "roofline_fraction": (bound / total) if total else 0.0,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens."""
    n = active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens


def active_param_count(cfg) -> float:
    """Parameter count, with MoE counting only routed-active experts."""
    import jax

    from repro.models.model import model_schema

    schema = model_schema(cfg)
    total = 0
    for path, p in jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=lambda x: hasattr(x, "axes")
    )[0]:
        n = 1
        for s in p.shape:
            n *= s
        keys = jax.tree_util.keystr(path)
        if "experts" in keys and cfg.num_experts:
            n = n * (cfg.top_k / cfg.num_experts)
        total += n
    return float(total)
