# Must precede every other import (see dryrun.py).
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Dry-run for the paper's own workload (``--arch bsi_paper``).

Lowers the dense-deformation-field expansion for each dataset volume
(paper Table 2) in each algorithm form, sharded over the production mesh:
the control grid is replicated (it is ~100x smaller than the field); the
output field is sharded over (data, model) on its x/y axes, so GSPMD emits
halo exchanges for the tile overlap — the distributed analogue of the
paper's Eq. (A.4) overlap accounting.

    PYTHONPATH=src python -m repro.launch.dryrun_bsi [--mesh pod|multipod|both]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.bsi_paper import BSI_WORKLOADS
from repro.core import ffd
from repro.launch.dryrun import RESULTS, _mem_dict
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms

MODES = ("gather", "tt", "ttli", "separable", "matmul")


def bsi_flops_model(volume, tile, mode, channels=3):
    """Analytic per-voxel op model (paper App. B + DESIGN.md).

    ``matmul`` is the dense (d^3, 64) basis contraction: 64 MACs per voxel
    regardless of tile — more model FLOPs than separable, but they run on
    the MXU at matrix-unit throughput instead of the VPU.
    """
    nvox = volume[0] * volume[1] * volume[2]
    d = tile[0]
    per_voxel = {
        "gather": 255, "tt": 255, "ttli": 126,
        "separable": 2 * (4 + 16 / d + 64 / d / d),
        "matmul": 2 * 64,
    }[mode]
    return nvox * per_voxel * channels


def lower_bsi(work, mode, multi_pod):
    mesh = make_production_mesh(multi_pod=multi_pod)
    gshape = ffd.grid_shape_for_volume(work.volume, work.tile)
    phi = jax.ShapeDtypeStruct(gshape + (work.channels,), jnp.float32)

    axes = mesh.axis_names
    out_spec = (PartitionSpec(("pod", "data"), "model", None, None)
                if "pod" in axes else
                PartitionSpec("data", "model", None, None))

    def expand(p):
        out = ffd.dense_field(p, work.tile, work.volume, mode=mode, impl="jnp")
        # constraint (not out_shardings): paper volumes are not divisible by
        # the mesh; GSPMD pads under a constraint.
        return jax.lax.with_sharding_constraint(out, out_spec)

    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = jax.jit(
            expand,
            in_shardings=NamedSharding(mesh, PartitionSpec()),
        ).lower(phi)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    hlo = analyze_hlo(compiled.as_text())
    n_chips = 512 if multi_pod else 256
    mf = bsi_flops_model(work.volume, work.tile, mode)
    return {
        "arch": "bsi_paper", "workload": work.name, "mode": mode,
        "tile": list(work.tile), "volume": list(work.volume),
        "mesh": "multipod" if multi_pod else "pod",
        "status": "ok", "chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": hlo.flops,
        "bytes_per_device": hlo.bytes_accessed,
        "collectives": {
            "per_kind_bytes": hlo.collective_bytes,
            "counts": hlo.collective_counts,
            "total_bytes": hlo.total_collective_bytes,
        },
        "memory_analysis": _mem_dict(compiled.memory_analysis()),
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / hlo.flops if hlo.flops else None,
        "roofline": roofline_terms(
            flops_per_device=hlo.flops,
            bytes_per_device=hlo.bytes_accessed,
            collective_bytes_per_device=hlo.total_collective_bytes,
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    RESULTS.mkdir(parents=True, exist_ok=True)
    for mesh_name in meshes:
        for work in BSI_WORKLOADS:
            for mode in MODES:
                path = RESULTS / f"bsi_paper__{work.name}-{mode}__{mesh_name}.json"
                if path.exists() and not args.force:
                    print(f"[cached] {path.name}")
                    continue
                try:
                    rec = lower_bsi(work, mode, mesh_name == "multipod")
                except Exception as e:
                    rec = {"arch": "bsi_paper", "workload": work.name,
                           "mode": mode, "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                path.write_text(json.dumps(rec, indent=1, default=str))
                print(f"[{rec['status']}] {path.name} "
                      + (f"compile={rec.get('compile_s')}s "
                         f"mem={rec['roofline']['memory_s']:.4f}s "
                         f"comp={rec['roofline']['compute_s']:.4f}s"
                         if rec["status"] == "ok" else ""), flush=True)


if __name__ == "__main__":
    main()
