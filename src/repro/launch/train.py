"""End-to-end training driver: config -> data -> sharded train loop.

Production behaviours wired in (exercised at smoke scale in tests and
``examples/train_lm.py``):
  * checkpoint/restart — atomic keep-k checkpoints, auto-resume from the
    latest on relaunch, preemption-signal save;
  * elastic restart — restore reshards onto whatever mesh the relaunch
    has (repro.checkpoint saves unsharded);
  * straggler watchdog — per-step wall time tracked; steps slower than
    ``straggler_factor`` x median are counted and surfaced (at real scale
    this feeds the re-scheduling hook);
  * gradient compression across the pod axis (optional).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
        --steps 50 --out /tmp/run1
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.optim.compression import make_compressor
from repro.optim.optimizer import OptConfig
from repro.training.steps import init_train_state, make_train_step

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    def __init__(self, cfg, ocfg, out_dir, *, seed=0, grad_accum=1,
                 compress=False, straggler_factor=3.0, keep=3):
        self.cfg = cfg
        self.ocfg = ocfg
        self.ckpt = Checkpointer(pathlib.Path(out_dir) / "ckpt", keep=keep)
        self.compressor = make_compressor() if compress else None
        self.step_fn = jax.jit(make_train_step(
            cfg, ocfg, rules=None, grad_accum=grad_accum,
            compressor=self.compressor,
        ))
        self.seed = seed
        self.step = 0
        self.state = None
        self.step_times = []
        self.straggler_factor = straggler_factor
        self.stragglers = 0

    def init_or_restore(self):
        self.state = init_train_state(self.cfg, self.ocfg, seed=self.seed)
        if self.compressor is not None:
            self.state["ef"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), self.state["params"])
        latest = self.ckpt.latest_step()
        if latest is not None:
            self.state, step, extra = self.ckpt.restore(self.state)
            self.step = int(extra.get("next_step", step))
        return self.step

    def run(self, pipeline: TokenPipeline, steps: int, ckpt_every=50,
            log_every=10, log=print):
        assert self.state is not None, "call init_or_restore() first"
        losses = []
        for s in range(self.step, steps):
            batch = {k: jnp.asarray(v) for k, v in pipeline.batch_at(s).items()}
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self._track_time(dt)
            losses.append(loss)
            self.step = s + 1
            if (s + 1) % log_every == 0:
                log(f"step {s+1}: loss={loss:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if (s + 1) % ckpt_every == 0:
                self.ckpt.save(s + 1, self.state,
                               {"next_step": s + 1, "loss": loss},
                               blocking=False)
        self.ckpt.save(self.step, self.state,
                       {"next_step": self.step,
                        "loss": losses[-1] if losses else None})
        self.ckpt.wait()
        return losses

    def _track_time(self, dt):
        if len(self.step_times) >= 5:
            med = statistics.median(self.step_times[-50:])
            if dt > self.straggler_factor * med:
                self.stragglers += 1  # at scale: trigger re-shard/re-schedule
        self.step_times.append(dt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--out", default="/tmp/repro_train")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    ocfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                     total_steps=args.steps)
    loop = TrainLoop(cfg, ocfg, args.out, grad_accum=args.grad_accum,
                     compress=args.compress)
    start = loop.init_or_restore()
    print(f"arch={cfg.name} (smoke={args.smoke}) starting at step {start}")
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))
    losses = loop.run(pipe, args.steps)
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "losses.json").write_text(json.dumps(losses))
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"stragglers observed: {loop.stragglers}")


if __name__ == "__main__":
    main()
