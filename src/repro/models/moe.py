"""Mixture-of-Experts FFN: top-k token-choice routing with *local* dispatch.

Ranking/capacity are computed per batch row (cumsum along the sequence only)
so no collective crosses the batch sharding during dispatch; the only
communication is the token->expert exchange implied by re-sharding the
capacity buffer from batch-sharded to expert-sharded (GSPMD lowers it as an
all-to-all — §Perf arctic iteration; the global-cumsum scatter baseline
generated collective-permute chains instead).

Capacity semantics: per-row GShard-style dropping (tokens beyond
``capacity_factor * S * k / E`` slots within their own row drop).  Supports
shared experts (qwen2-moe) and a dense parallel residual (arctic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.schema import P, lead

__all__ = ["moe_schema", "apply_moe"]


def moe_schema(cfg, layers=None):
    d, fe = cfg.d_model, cfg.moe_d_ff
    E = cfg.num_experts
    pre, ax = lead(layers)
    s = {
        "router": P(pre + (d, E), ax + ("embed", None), scale=0.02),
        "experts": {
            "wi_gate": P(pre + (E, d, fe), ax + ("experts", "embed", "expert_ff")),
            "wi_up": P(pre + (E, d, fe), ax + ("experts", "embed", "expert_ff")),
            "wo": P(pre + (E, fe, d), ax + ("experts", "expert_ff", "embed")),
        },
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * fe  # qwen2-moe: fused shared expert
        s["shared"] = {
            "wi_gate": P(pre + (d, fs), ax + ("embed", "ff")),
            "wi_up": P(pre + (d, fs), ax + ("embed", "ff")),
            "wo": P(pre + (fs, d), ax + ("ff", "embed")),
        }
        s["shared_gate"] = P(pre + (d,), ax + ("embed",), scale=0.02)
    return s


def _expert_ffn(experts, x):
    """x: (E, C, D) -> (E, C, D); batched GLU over the expert dim."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, experts["wi_gate"]))
    u = jnp.einsum("ecd,edf->ecf", x, experts["wi_up"])
    return jnp.einsum("ecf,efd->ecd", g * u, experts["wo"])


def apply_moe(p, x, cfg, rules=None):
    """x: (B, S, D) -> (B, S, D), plus the Switch load-balancing aux loss."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e  (global means)
    me = probs.mean((0, 1))
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=(0, 1, 2))
    aux = E * jnp.sum(me * ce)

    cap = int(cfg.capacity_factor * S * k / E) + 1

    flat_e = expert_ids.reshape(B, S * k)                     # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=1) - 1                     # local to the row
    my_rank = jnp.take_along_axis(rank, flat_e[:, :, None], axis=2)[..., 0]
    valid = my_rank < cap
    slot = jnp.where(valid, flat_e * cap + my_rank, E * cap)  # (B, S*k)

    x_rep = jnp.repeat(x, k, axis=1)                          # (B, S*k, D)
    rows = jnp.arange(B)[:, None]
    buf = jnp.zeros((B, E * cap + 1, D), x.dtype).at[rows, slot].add(x_rep)
    ebuf = buf[:, :-1].reshape(B, E, cap, D)
    # token -> expert exchange: batch-sharded -> expert-sharded (all-to-all)
    ebuf = constrain(ebuf, (None, "experts", None, None), rules)
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", ebuf, p["experts"]["wi_gate"]))
    u = jnp.einsum("becd,edf->becf", ebuf, p["experts"]["wi_up"])
    h = jnp.einsum("becf,efd->becd", g * u, p["experts"]["wo"])
    # expert -> token exchange back
    h = constrain(h, ("batch", None, None, None), rules)
    h = h.reshape(B, E * cap, D)
    h = jnp.concatenate([h, jnp.zeros((B, 1, D), h.dtype)], axis=1)
    y = (h[rows, slot] * gate_vals.reshape(B, S * k, 1).astype(h.dtype))
    y = y.reshape(B, S, k, D).sum(2)

    if "shared" in p:  # qwen2-moe: always-on shared expert, sigmoid-gated
        sh = p["shared"]
        xf = x.reshape(B * S, D)
        g = jax.nn.silu(jnp.einsum("nd,df->nf", xf, sh["wi_gate"]))
        u = jnp.einsum("nd,df->nf", xf, sh["wi_up"])
        ys = jnp.einsum("nf,fd->nd", g * u, sh["wo"])
        sg = jax.nn.sigmoid(jnp.einsum("nd,d->n", xf.astype(jnp.float32),
                                       p["shared_gate"].astype(jnp.float32)))
        y = y + (ys * sg[:, None].astype(y.dtype)).reshape(B, S, D)
    return y, aux
