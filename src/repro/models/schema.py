"""Parameter schemas: one declaration -> real init, abstract init, shardings.

Every model declares its parameters as a nested dict of ``P(shape, axes)``
leaves, where ``axes`` are *logical* axis names ("embed", "heads", "ff",
"vocab", "experts", "layers", ...).  From that single declaration we derive:

* ``init_params``      — real, deterministically-seeded arrays (smoke tests);
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` tree (dry-run lowering —
                          full-size models are never allocated);
* ``partition_specs``  — ``PartitionSpec`` tree via the run's logical->mesh
                          axis rules (``repro.distributed.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["P", "init_params", "abstract_params", "map_schema", "lead"]


def lead(layers):
    """(shape-prefix, axes-prefix) for stacked-layer params.

    ``layers`` may be None (unstacked), an int (one scan level) or a tuple
    (nested scans, e.g. (groups, layers-per-group))."""
    if layers is None:
        return (), ()
    if isinstance(layers, int):
        layers = (layers,)
    return tuple(layers), ("layers",) * len(layers)


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter declaration."""
    shape: tuple
    axes: tuple            # logical axis name (or None) per dim
    init: str = "normal"   # normal | zeros | ones
    scale: Optional[float] = None  # default: 1/sqrt(fan_in-ish)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x):
    return isinstance(x, P)


def map_schema(fn, schema):
    return jax.tree_util.tree_map(fn, schema, is_leaf=_is_leaf)


def _leaf_scale(p: P) -> float:
    if p.scale is not None:
        return p.scale
    fan_in = p.shape[0] if len(p.shape) >= 2 else max(p.shape[-1], 1)
    # stacked-layer params: fan-in is the second axis
    if p.axes and p.axes[0] == "layers" and len(p.shape) >= 3:
        fan_in = p.shape[1]
    return 1.0 / float(np.sqrt(max(fan_in, 1)))


def init_params(schema, rng, dtype=jnp.float32):
    """Materialise real parameters (used at smoke scale only)."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=_is_leaf
    )[0]

    out = {}
    for path, p in leaves_with_path:
        key = jax.random.fold_in(rng, hash(jax.tree_util.keystr(path)) % 2**31)
        if p.init == "zeros":
            val = jnp.zeros(p.shape, dtype)
        elif p.init == "ones":
            val = jnp.ones(p.shape, dtype)
        else:
            val = (jax.random.normal(key, p.shape, dtype) * _leaf_scale(p)).astype(dtype)
        _set_path(out, path, val)
    return out


def abstract_params(schema, dtype=jnp.float32):
    """ShapeDtypeStruct tree — dry-run stand-in, no allocation."""
    return map_schema(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), schema)


def _set_path(tree, path, val):
    node = tree
    keys = [k.key for k in path]
    for k in keys[:-1]:
        node = node.setdefault(k, {})
    node[keys[-1]] = val
