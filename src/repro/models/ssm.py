"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and Mamba-style SSM.

All three expose a parallel/chunked *train* form plus an O(1)-state *decode*
step — the property that makes the ``long_500k`` cell feasible for the
ssm/hybrid archs (DESIGN.md §6.9).

* mLSTM (xLSTM, arXiv:2405.04517): matrix-memory cell, chunked-parallel
  within ``chunk`` tokens and recurrent across chunks (carry C, n, m).
* sLSTM: scalar-memory cell with exponential gating — inherently sequential,
  implemented as a ``lax.scan`` over time.
* Mamba (arXiv:2312.00752): selective diagonal SSM via associative scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.schema import P, lead

__all__ = [
    "mlstm_schema", "mlstm_apply", "mlstm_decode", "mlstm_init_state",
    "slstm_schema", "slstm_apply", "slstm_decode", "slstm_init_state",
    "mamba_schema", "mamba_apply", "mamba_decode", "mamba_init_state",
]


# ------------------------------------------------------------------- mLSTM

def mlstm_schema(d, n_heads, layers=None):
    pre, ax = lead(layers)
    return {
        "wq": P(pre + (d, d), ax + ("embed", "heads")),
        "wk": P(pre + (d, d), ax + ("embed", "heads")),
        "wv": P(pre + (d, d), ax + ("embed", "heads")),
        "wi": P(pre + (d, n_heads), ax + ("embed", None), scale=0.02),
        "wf": P(pre + (d, n_heads), ax + ("embed", None), scale=0.02),
        "bf": P(pre + (n_heads,), ax + (None,), init="ones"),
        "wo": P(pre + (d, d), ax + ("heads", "embed")),
        "gate": P(pre + (d, d), ax + ("embed", None), scale=0.02),
    }


def _heads(x, h):
    B, S, E = x.shape
    return x.reshape(B, S, h, E // h)


def _mlstm_proj(p, x, n_heads):
    q = _heads(jnp.einsum("bsd,de->bse", x, p["wq"]), n_heads)
    k = _heads(jnp.einsum("bsd,de->bse", x, p["wk"]), n_heads) / jnp.sqrt(q.shape[-1])
    v = _heads(jnp.einsum("bsd,de->bse", x, p["wv"]), n_heads)
    logi = jnp.einsum("bsd,dh->bsh", x, p["wi"]).astype(jnp.float32)
    logf = (jnp.einsum("bsd,dh->bsh", x, p["wf"]) + p["bf"]).astype(jnp.float32)
    logf = -jax.nn.softplus(-logf)  # log sigmoid
    return q, k, v, logi, logf


def mlstm_init_state(batch, n_heads, hd):
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def mlstm_apply(p, x, state=None, chunk=256):
    """x: (B, S, D). Chunkwise-parallel mLSTM; returns (y, final_state)."""
    B, S, D = x.shape
    H = p["wi"].shape[-1]
    hd = p["wq"].shape[-1] // H
    chunk = min(chunk, S)
    if S % chunk:  # pad to a chunk multiple (masked by gates ~ benign for smoke)
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    q, k, v, logi, logf = _mlstm_proj(p, x, H)
    Sp = x.shape[1]
    n_chunks = Sp // chunk

    def to_chunks(a):
        return a.reshape(B, n_chunks, chunk, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lic, lfc = map(to_chunks, (q, k, v, logi, logf))
    state = state or mlstm_init_state(B, H, hd)

    def step(carry, xs):
        C, n, m = carry["C"], carry["n"], carry["m"]
        qi, ki, vi, li, lf = xs  # (B, c, H, ...)
        csum = jnp.cumsum(lf, axis=1)                      # within-chunk log decay
        total = csum[:, -1]                                # (B, H)
        # log "a" for inter-chunk carry-in and "b" for writing to the carry
        log_in = li + (total[:, None] - csum)              # decay to chunk end
        m_new = jnp.maximum(m + total, log_in.max(1))      # (B, H) stabiliser
        # intra-chunk attention-like term
        decay = csum[:, :, None, :] - csum[:, None, :, :]  # (B, t, s, H) t>=s
        logD = decay + li[:, None]                         # + log i_s
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        logD = jnp.where(mask[None, :, :, None], logD, -jnp.inf)
        row_m = jnp.maximum(logD.max(2), m[:, None] + csum)  # (B, t, H)
        Dmat = jnp.exp(logD - row_m[:, :, None])
        s = jnp.einsum("bthk,bshk->btsh", qi, ki).astype(jnp.float32)
        intra = jnp.einsum("btsh,btsh,bshk->bthk", s, Dmat, vi.astype(jnp.float32))
        norm_intra = jnp.einsum("btsh,btsh->bth", s, Dmat)
        # inter-chunk: carry state decayed to each position
        carry_scale = jnp.exp(m[:, None] + csum - row_m)   # (B, t, H)
        inter = jnp.einsum("bthk,bhkl->bthl", qi.astype(jnp.float32), C) * carry_scale[..., None]
        norm_inter = jnp.einsum("bthk,bhk->bth", qi.astype(jnp.float32), n) * carry_scale
        num = intra + inter
        den = jnp.abs(norm_intra + norm_inter) + jnp.exp(-row_m)
        y = num / jnp.maximum(den, 1e-6)[..., None]
        # update carry
        w = jnp.exp(log_in - m_new[:, None])               # (B, c, H)
        C = C * jnp.exp(m + total - m_new)[..., None, None] + jnp.einsum(
            "bsh,bshk,bshl->bhkl", w, ki.astype(jnp.float32), vi.astype(jnp.float32)
        )
        n = n * jnp.exp(m + total - m_new)[..., None] + jnp.einsum(
            "bsh,bshk->bhk", w, ki.astype(jnp.float32)
        )
        return {"C": C, "n": n, "m": m_new}, y.astype(x.dtype)

    state, yc = jax.lax.scan(step, state, (qc, kc, vc, lic, lfc))
    y = yc.swapaxes(0, 1).reshape(B, Sp, H * hd)[:, :S]
    g = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x[:, :S], p["gate"]))
    out = jnp.einsum("bse,ed->bsd", y, p["wo"]) * g
    return out, state


def mlstm_decode(p, x, state):
    """x: (B, 1, D) single step. Returns (y, new_state)."""
    H = p["wi"].shape[-1]
    q, k, v, logi, logf = _mlstm_proj(p, x, H)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]          # (B, H, hd)
    li, lf = logi[:, 0], logf[:, 0]              # (B, H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)[..., None, None]
    iw = jnp.exp(li - m_new)[..., None, None]
    C = C * fw + iw * (k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :])
    n = n * fw[..., 0] + iw[..., 0] * k.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkl->bhl", q.astype(jnp.float32), C)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n)) + jnp.exp(-m_new)
    y = (num / jnp.maximum(den, 1e-6)[..., None]).astype(x.dtype)
    g = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["gate"]))
    B, Hh, hd = y.shape
    out = jnp.einsum("be,ed->bd", y.reshape(B, Hh * hd), p["wo"])[:, None] * g
    return out, {"C": C, "n": n, "m": m_new}


# ------------------------------------------------------------------- sLSTM

def slstm_schema(d, n_heads, layers=None):
    hd = d // n_heads
    pre, ax = lead(layers)
    return {
        "wz": P(pre + (d, d), ax + ("embed", "heads")),
        "wi": P(pre + (d, d), ax + ("embed", "heads"), scale=0.02),
        "wf": P(pre + (d, d), ax + ("embed", "heads"), scale=0.02),
        "bf": P(pre + (n_heads, hd), ax + (None, None), init="ones"),
        "wo_gate": P(pre + (d, d), ax + ("embed", "heads"), scale=0.02),
        "wo": P(pre + (d, d), ax + ("heads", "embed")),
    }


def slstm_init_state(batch, n_heads, hd):
    z = jnp.zeros((batch, n_heads, hd), jnp.float32)
    return {"c": z, "n": z, "m": z - 1e30}


def _slstm_gates(p, x):
    H, hd = p["bf"].shape[-2], p["bf"].shape[-1]
    z = jnp.tanh(_heads(jnp.einsum("bsd,de->bse", x, p["wz"]), H).astype(jnp.float32))
    li = _heads(jnp.einsum("bsd,de->bse", x, p["wi"]), H).astype(jnp.float32)
    lf = (_heads(jnp.einsum("bsd,de->bse", x, p["wf"]), H) + p["bf"]).astype(jnp.float32)
    lf = -jax.nn.softplus(-lf)
    o = jax.nn.sigmoid(_heads(jnp.einsum("bsd,de->bse", x, p["wo_gate"]), H).astype(jnp.float32))
    return z, li, lf, o


def _slstm_step(state, xs):
    z, li, lf, o = xs
    c, n, m = state["c"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    c = fw * c + iw * z
    n = fw * n + iw
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new}, h


def slstm_apply(p, x, state=None):
    B, S, D = x.shape
    H, hd = p["bf"].shape
    z, li, lf, o = _slstm_gates(p, x)
    state = state or slstm_init_state(B, H, hd)
    xs = tuple(a.swapaxes(0, 1) for a in (z, li, lf, o))  # (S, B, H, hd)
    state, h = jax.lax.scan(_slstm_step, state, xs)
    h = h.swapaxes(0, 1).astype(x.dtype)
    B, S, H, hd = h.shape
    return jnp.einsum("bse,ed->bsd", h.reshape(B, S, H * hd), p["wo"]), state


def slstm_decode(p, x, state):
    z, li, lf, o = _slstm_gates(p, x)
    state, h = _slstm_step(state, tuple(a[:, 0] for a in (z, li, lf, o)))
    B, H, hd = h.shape
    out = jnp.einsum("be,ed->bd", h.astype(x.dtype).reshape(B, H * hd),
                     p["wo"])[:, None]
    return out, state


# ------------------------------------------------------------------- Mamba

def mamba_schema(d, d_state, expand=2, conv=4, layers=None):
    di = expand * d
    pre, ax = lead(layers)
    return {
        "in_proj": P(pre + (d, 2 * di), ax + ("embed", "ff")),
        "conv_w": P(pre + (conv, di), ax + (None, "ff"), scale=0.5),
        "conv_b": P(pre + (di,), ax + ("ff",), init="zeros"),
        "x_bc": P(pre + (di, 2 * d_state), ax + ("ff", None)),
        "x_dt": P(pre + (di,), ax + ("ff",), scale=0.1),
        "dt_bias": P(pre + (di,), ax + ("ff",), init="zeros"),
        "a_log": P(pre + (di, d_state), ax + ("ff", None), init="ones"),
        "dskip": P(pre + (di,), ax + ("ff",), init="ones"),
        "out_proj": P(pre + (di, d), ax + ("ff", "embed")),
    }


def mamba_init_state(batch, di, d_state, conv=4):
    return {
        "ssm": jnp.zeros((batch, di, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv - 1, di), jnp.float32),
    }


def _mamba_pre(p, xz, conv_ctx=None):
    """Split, causal conv, and SSM parameter computation."""
    di = p["conv_b"].shape[-1]
    x, z = xz[..., :di], xz[..., di:]
    conv = p["conv_w"].shape[0]
    if conv_ctx is None:
        xp = jnp.pad(x, ((0, 0), (conv - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_ctx.astype(x.dtype), x], axis=1)
    xc = sum(
        xp[:, i : i + x.shape[1]] * p["conv_w"][i] for i in range(conv)
    ) + p["conv_b"]
    xc = jax.nn.silu(xc)
    ds = p["a_log"].shape[-1]
    bc = jnp.einsum("bsf,fn->bsn", xc, p["x_bc"])
    Bm, Cm = bc[..., :ds], bc[..., ds:]
    dt = jax.nn.softplus(
        jnp.einsum("bsf,f->bs", xc, p["x_dt"])[..., None] + p["dt_bias"]
    ).astype(jnp.float32)  # (B, S, di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, ds)
    return x, z, xc, Bm, Cm, dt, A, xp


def mamba_apply(p, x_in, state=None):
    """x_in: (B, S, D) -> (B, S, D). Associative scan over time."""
    B, S, D = x_in.shape
    xz = jnp.einsum("bsd,de->bse", x_in, p["in_proj"])
    conv_ctx = None if state is None else state["conv"]
    x, z, xc, Bm, Cm, dt, A, xp = _mamba_pre(p, xz, conv_ctx)
    # discretise: h_t = exp(dt*A) h_{t-1} + dt * B_t * x_t
    decay = jnp.exp(dt[..., None] * A)                       # (B, S, di, ds)
    inp = dt[..., None] * Bm[:, :, None, :] * xc[..., None].astype(jnp.float32)
    if state is not None:
        inp = inp.at[:, 0].add(decay[:, 0] * state["ssm"])

    def combine(a, b):
        da, ia = a
        db, ib = b
        return da * db, ib + db * ia

    dec, h = jax.lax.associative_scan(combine, (decay, inp), axis=1)
    y = jnp.einsum("bsfn,bsn->bsf", h, Cm.astype(jnp.float32))
    y = y + p["dskip"] * xc.astype(jnp.float32)
    y = y.astype(x_in.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"])
    new_state = None
    if state is not None:
        conv = p["conv_w"].shape[0]
        new_state = {"ssm": h[:, -1], "conv": xp[:, -(conv - 1):].astype(jnp.float32)}
    return out, new_state


def mamba_decode(p, x_in, state):
    out, new_state = mamba_apply(p, x_in, state)
    return out, new_state
