"""Shared neural layers: norms, RoPE, MLPs, embeddings, chunked loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.schema import P, lead

__all__ = [
    "rmsnorm", "layernorm", "norm_schema", "apply_norm",
    "rope", "glu_mlp", "gelu_mlp", "mlp_schema", "apply_mlp",
    "embed_schema", "chunked_xent",
]


def norm_schema(d, kind="rmsnorm", layers=None):
    pre, ax = lead(layers)
    s = {"scale": P(pre + (d,), ax + ("embed",), init="ones")}
    if kind == "layernorm":
        s["bias"] = P(pre + (d,), ax + ("embed",), init="zeros")
    return s


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return (y + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"], eps)
    return rmsnorm(x, p["scale"], eps)


def rope(x, positions, theta=10_000.0):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def mlp_schema(d, f, act="silu", layers=None):
    pre, ax = lead(layers)
    if act == "silu":  # GLU: gate + up + down
        return {
            "wi_gate": P(pre + (d, f), ax + ("embed", "ff")),
            "wi_up": P(pre + (d, f), ax + ("embed", "ff")),
            "wo": P(pre + (f, d), ax + ("ff", "embed")),
        }
    return {  # plain MLP (whisper-style)
        "wi": P(pre + (d, f), ax + ("embed", "ff")),
        "bi": P(pre + (f,), ax + ("ff",), init="zeros"),
        "wo": P(pre + (f, d), ax + ("ff", "embed")),
        "bo": P(pre + (d,), ax + ("embed",), init="zeros"),
    }


def glu_mlp(p, x):
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["wi_gate"]))
    u = jnp.einsum("...d,df->...f", x, p["wi_up"])
    return jnp.einsum("...f,fd->...d", g * u, p["wo"])


def gelu_mlp(p, x):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["wi"]) + p["bi"])
    return jnp.einsum("...f,fd->...d", h, p["wo"]) + p["bo"]


def apply_mlp(p, x, act="silu"):
    return glu_mlp(p, x) if act == "silu" else gelu_mlp(p, x)


def embed_schema(vocab, d):
    return {"table": P((vocab, d), ("vocab", "embed"), scale=1.0)}


def chunked_xent(h, embed_table, labels, chunk=1024, final_softcap=0.0):
    """Sequence-chunked cross-entropy: bounds the (tokens, vocab) logits.

    h: (B, S, D) final hidden states; labels: (B, S) int32 (-1 = masked).
    Returns mean NLL over unmasked tokens.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = h.reshape(B, n, chunk, D).swapaxes(0, 1)        # (n, B, c, D)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)       # (n, B, c)

    def body(carry, xs):
        tot, cnt = carry
        hh, ll = xs
        logits = jnp.einsum("bcd,vd->bcv", hh.astype(jnp.float32),
                            embed_table.astype(jnp.float32))
        if final_softcap:
            logits = jnp.tanh(logits / final_softcap) * final_softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        ix = jnp.clip(ll, 0, logits.shape[-1] - 1)
        gold = jnp.take_along_axis(logits, ix[..., None], axis=-1)[..., 0]
        mask = (ll >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
