"""Attention: GQA projections, blockwise (flash-style) softmax, KV caches.

Blockwise attention scans over query and KV chunks with online-softmax
accumulators (the jnp analogue of FlashAttention) so 32k-token prefill never
materialises an (S, S) score matrix.  Supports causal masking, sliding
windows (gemma local layers), logit softcaps (gemma2) and cross-attention
(whisper / llama-vision).  Decode reads a bf16 or int8-quantised KV cache;
int8 uses per-(token, head) scales (KIVI-style) to fit 32k x 128 caches in
HBM (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rope
from repro.models.schema import P, lead

__all__ = [
    "attn_schema", "project_qkv", "attend_blockwise", "attend_full",
    "cache_schema_shapes", "init_cache", "update_cache", "read_cache",
    "decode_attend", "out_proj",
]

NEG_INF = -2.0e30


def attn_schema(d, n_heads, n_kv, hd, qkv_bias=False, layers=None, prefix=""):
    """Head dims stored flattened (d, H*hd): H*hd is divisible by the 16-way
    model axis for every assigned arch, while H alone often is not."""
    pre, ax = lead(layers)
    s = {
        "wq": P(pre + (d, n_heads * hd), ax + ("embed", "heads")),
        "wk": P(pre + (d, n_kv * hd), ax + ("embed", "kv_heads")),
        "wv": P(pre + (d, n_kv * hd), ax + ("embed", "kv_heads")),
        "wo": P(pre + (n_heads * hd, d), ax + ("heads", "embed")),
    }
    if qkv_bias:
        s["bq"] = P(pre + (n_heads * hd,), ax + ("heads",), init="zeros")
        s["bk"] = P(pre + (n_kv * hd,), ax + ("kv_heads",), init="zeros")
        s["bv"] = P(pre + (n_kv * hd,), ax + ("kv_heads",), init="zeros")
    return s


def proj_heads(w, x, n_heads, bias=None):
    """x (B,S,D) @ w (D, H*hd) -> (B, S, H, hd)."""
    y = jnp.einsum("bsd,de->bse", x, w)
    if bias is not None:
        y = y + bias
    B, S, E = y.shape
    return y.reshape(B, S, n_heads, E // n_heads)


def project_qkv(p, x, positions, rope_theta=10_000.0, use_rope=True,
                n_heads=None, n_kv=None):
    """x: (B, S, D) -> q (B, S, H, hd), k/v (B, S, KV, hd)."""
    hd_total = p["wq"].shape[-1]
    kv_total = p["wk"].shape[-1]
    if n_heads is None:  # infer: hd == kv_total // n_kv == hd_total // n_heads
        n_heads, n_kv = _infer_heads(hd_total, kv_total)
    q = proj_heads(p["wq"], x, n_heads, p.get("bq"))
    k = proj_heads(p["wk"], x, n_kv, p.get("bk"))
    v = proj_heads(p["wv"], x, n_kv, p.get("bv"))
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    return q, k, v


_HEAD_HINTS = {}


def set_head_hint(hd_total, kv_total, n_heads, n_kv):
    _HEAD_HINTS[(hd_total, kv_total)] = (n_heads, n_kv)


def _infer_heads(hd_total, kv_total):
    if (hd_total, kv_total) in _HEAD_HINTS:
        return _HEAD_HINTS[(hd_total, kv_total)]
    raise ValueError(
        f"cannot infer head split for ({hd_total}, {kv_total}); call "
        "set_head_hint or pass n_heads/n_kv")


def out_proj(p, o):
    B, S, H, hd = o.shape
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * hd), p["wo"])


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None], (B, S, KV, n_rep, hd)).reshape(
        B, S, KV * n_rep, hd
    )


def _mask_bias(q_pos, k_pos, causal, window, dtype=jnp.float32):
    """(Q, K) additive mask. window > 0 keeps k_pos > q_pos - window."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    # `window` may be a traced scalar (per-layer flag under scan): 0 = full.
    win_ok = k_pos[None, :] > (q_pos[:, None] - jnp.maximum(window, 1))
    ok &= jnp.where(window > 0, win_ok, True)
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def attend_full(q, k, v, *, q_positions, k_positions, causal=True, window=0,
                softcap=0.0):
    """Unchunked attention (short sequences / smoke tests)."""
    hd = q.shape[-1]
    n_rep = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    s = jnp.einsum("bqhd,bmhd->bhqm", q, k).astype(jnp.float32) / jnp.sqrt(hd)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = s + _mask_bias(q_positions, k_positions, causal, window)[None, None]
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqm,bmhk->bqhk", w.astype(v.dtype), v)


def attend_blockwise(q, k, v, *, q_positions, k_positions, causal=True,
                     window=0, softcap=0.0, q_chunk=1024, kv_chunk=1024):
    """Flash-style blockwise attention: scan q chunks x kv chunks."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    n_rep = H // k.shape[2]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    if Sq % q_chunk or Sk % kv_chunk:
        return attend_full(q, k, v, q_positions=q_positions,
                           k_positions=k_positions, causal=causal,
                           window=window, softcap=softcap)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    qc = q.reshape(B, nq, q_chunk, H, hd).swapaxes(0, 1)
    qp = q_positions.reshape(nq, q_chunk)
    kc = k.reshape(B, nk, kv_chunk, *k.shape[2:]).swapaxes(0, 1)
    vc = v.reshape(B, nk, kv_chunk, *v.shape[2:]).swapaxes(0, 1)
    kp = k_positions.reshape(nk, kv_chunk)
    scale = 1.0 / jnp.sqrt(hd)

    def q_step(_, q_xs):
        qi, qpi = q_xs

        def kv_step(carry, kv_xs):
            m, l, acc = carry
            ki, vi, kpi = kv_xs
            kk = _repeat_kv(ki, n_rep)
            vv = _repeat_kv(vi, n_rep)
            s = jnp.einsum("bqhk,bmhk->bhqm", qi, kk).astype(jnp.float32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            s = s + _mask_bias(qpi, kpi, causal, window)[None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqm,bmhk->bhqk", p, vv.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kp))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.swapaxes(1, 2).astype(q.dtype)  # (B, q_chunk, H, hd)

    _, oc = jax.lax.scan(q_step, None, (qc, qp))
    return oc.swapaxes(0, 1).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------- KV caches

def cache_schema_shapes(cfg, batch, max_len):
    """Shapes/dtypes of one layer-stack's KV cache (leading layers axis)."""
    hd = cfg.resolved_head_dim
    L, KV = cfg.num_layers, cfg.num_kv_heads
    base = dict(
        k=((L, batch, max_len, KV, hd), cfg.kv_cache_dtype),
        v=((L, batch, max_len, KV, hd), cfg.kv_cache_dtype),
    )
    if cfg.kv_cache_dtype == "int8":
        base["k_scale"] = ((L, batch, max_len, KV), "float32")
        base["v_scale"] = ((L, batch, max_len, KV), "float32")
    return base


def init_cache(cfg, batch, max_len):
    out = {
        name: jnp.zeros(shape, jnp.dtype(dt))
        for name, (shape, dt) in cache_schema_shapes(cfg, batch, max_len).items()
    }
    out["pos"] = jnp.zeros((), jnp.int32)
    return out


def _quant_int8(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
    return q, scale


def update_cache(cache_layer, k_new, v_new, pos, quantized):
    """Write (B, S_new, KV, hd) keys/values at offset ``pos``."""
    if quantized:
        kq, ks = _quant_int8(k_new)
        vq, vs = _quant_int8(v_new)
        return dict(
            k=jax.lax.dynamic_update_slice(cache_layer["k"], kq, (0, pos, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache_layer["v"], vq, (0, pos, 0, 0)),
            k_scale=jax.lax.dynamic_update_slice(cache_layer["k_scale"], ks, (0, pos, 0)),
            v_scale=jax.lax.dynamic_update_slice(cache_layer["v_scale"], vs, (0, pos, 0)),
        )
    return dict(
        k=jax.lax.dynamic_update_slice(
            cache_layer["k"], k_new.astype(cache_layer["k"].dtype), (0, pos, 0, 0)
        ),
        v=jax.lax.dynamic_update_slice(
            cache_layer["v"], v_new.astype(cache_layer["v"].dtype), (0, pos, 0, 0)
        ),
    )


def read_cache(cache_layer, compute_dtype):
    if "k_scale" in cache_layer:
        k = cache_layer["k"].astype(jnp.float32) * cache_layer["k_scale"][..., None]
        v = cache_layer["v"].astype(jnp.float32) * cache_layer["v_scale"][..., None]
        return k.astype(compute_dtype), v.astype(compute_dtype)
    return (
        cache_layer["k"].astype(compute_dtype),
        cache_layer["v"].astype(compute_dtype),
    )


def decode_attend(q, k_cache, v_cache, *, q_pos, cache_len, window=0, softcap=0.0):
    """Single-step decode attention over the full cache with a length mask.

    q: (B, 1, H, hd); k/v_cache: (B, S_max, KV, hd) already dequantised.
    """
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    n_rep = H // k_cache.shape[2]
    kk = _repeat_kv(k_cache, n_rep)
    vv = _repeat_kv(v_cache, n_rep)
    s = jnp.einsum("bqhk,bmhk->bhqm", q, kk).astype(jnp.float32) / jnp.sqrt(hd)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(S)
    ok = kpos[None, None, None, :] <= q_pos
    ok &= kpos[None, None, None, :] < cache_len
    win_ok = kpos[None, None, None, :] > (q_pos - jnp.maximum(window, 1))
    ok &= jnp.where(window > 0, win_ok, True)
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqm,bmhk->bqhk", w.astype(vv.dtype), vv)
