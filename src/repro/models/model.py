"""Composable model definitions for the 10 assigned architectures.

One schema/forward/prefill/decode family covers dense, MoE and hybrid
decoders (per-layer sliding-window flags handle gemma's local:global
patterns and hymba's 3 full-attention layers without breaking the layer
scan); xLSTM, enc-dec (whisper) and VLM (llama-vision) get their own
stacks.  Everything scans over stacked layer parameters (small HLO, fast
512-way SPMD compiles) with optional remat.

Simplifications vs the exact HF checkpoints are listed in DESIGN.md §6.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models.layers import (
    apply_mlp, apply_norm, chunked_xent, embed_schema, mlp_schema, norm_schema,
)
from repro.models.schema import P, abstract_params, init_params

__all__ = [
    "model_schema", "init_model", "abstract_model",
    "forward_train", "loss_fn", "prefill", "decode_step",
    "abstract_cache", "init_decode_cache",
]


# ----------------------------------------------------------------- schemas

def _decoder_blocks_schema(cfg: ModelConfig, L: int):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    s = {
        "ln1": norm_schema(d, cfg.norm, layers=L),
        "attn": attn.attn_schema(d, cfg.num_heads, cfg.num_kv_heads, hd,
                                 cfg.qkv_bias, layers=L),
        "ln2": norm_schema(d, cfg.norm, layers=L),
    }
    if cfg.num_experts:
        s["moe"] = moe_lib.moe_schema(cfg, layers=L)
        if cfg.dense_residual and cfg.d_ff:
            s["mlp"] = mlp_schema(d, cfg.d_ff, cfg.act, layers=L)
    elif cfg.d_ff:
        s["mlp"] = mlp_schema(d, cfg.d_ff, cfg.act, layers=L)
    if cfg.family == "hybrid":
        s["ln_mamba"] = norm_schema(d, cfg.norm, layers=L)
        s["mamba"] = ssm.mamba_schema(d, cfg.ssm_state, cfg.mamba_expand,
                                      cfg.mamba_conv, layers=L)
    return s


def _xlstm_schema(cfg: ModelConfig):
    assert cfg.slstm_every > 0
    g = cfg.slstm_every                    # group size: (g-1) mLSTM + 1 sLSTM
    G = cfg.num_layers // g
    d = cfg.d_model
    return {
        "embed": embed_schema(cfg.vocab_size, d),
        "groups": {
            "m_ln": norm_schema(d, cfg.norm, layers=(G, g - 1)),
            "mlstm": ssm.mlstm_schema(d, cfg.num_heads, layers=(G, g - 1)),
            "s_ln": norm_schema(d, cfg.norm, layers=G),
            "slstm": ssm.slstm_schema(d, cfg.num_heads, layers=G),
        },
        "final_norm": norm_schema(d, cfg.norm),
    }


def _encdec_schema(cfg: ModelConfig):
    d = cfg.d_model
    Le, Ld = cfg.encoder_layers, cfg.num_layers - cfg.encoder_layers
    enc = {
        "ln1": norm_schema(d, cfg.norm, layers=Le),
        "attn": attn.attn_schema(d, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, layers=Le),
        "ln2": norm_schema(d, cfg.norm, layers=Le),
        "mlp": mlp_schema(d, cfg.d_ff, cfg.act, layers=Le),
    }
    dec = {
        "ln1": norm_schema(d, cfg.norm, layers=Ld),
        "attn": attn.attn_schema(d, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, layers=Ld),
        "ln_x": norm_schema(d, cfg.norm, layers=Ld),
        "xattn": attn.attn_schema(d, cfg.num_heads, cfg.num_kv_heads,
                                  cfg.resolved_head_dim, layers=Ld),
        "ln2": norm_schema(d, cfg.norm, layers=Ld),
        "mlp": mlp_schema(d, cfg.d_ff, cfg.act, layers=Ld),
    }
    return {
        "embed": embed_schema(cfg.vocab_size, d),
        "encoder": enc,
        "enc_final_norm": norm_schema(d, cfg.norm),
        "decoder": dec,
        "final_norm": norm_schema(d, cfg.norm),
    }


def _vlm_schema(cfg: ModelConfig):
    d = cfg.d_model
    k = cfg.cross_attn_every
    G = cfg.num_layers // k                # groups of (k-1) self + 1 cross
    base = _decoder_blocks_schema(cfg, (G, k - 1))
    cross = {
        "ln": norm_schema(d, cfg.norm, layers=G),
        "xattn": attn.attn_schema(d, cfg.num_heads, cfg.num_kv_heads,
                                  cfg.resolved_head_dim, layers=G),
        "gate_attn": P((G,), ("layers",), init="zeros"),
        "ln_mlp": norm_schema(d, cfg.norm, layers=G),
        "mlp": mlp_schema(d, cfg.d_ff, cfg.act, layers=G),
        "gate_mlp": P((G,), ("layers",), init="zeros"),
    }
    return {
        "embed": embed_schema(cfg.vocab_size, d),
        "groups": {"self": base, "cross": cross},
        "final_norm": norm_schema(d, cfg.norm),
    }


def model_schema(cfg: ModelConfig):
    if cfg.family == "ssm":
        s = _xlstm_schema(cfg)
    elif cfg.family == "encdec":
        s = _encdec_schema(cfg)
    elif cfg.family == "vlm":
        s = _vlm_schema(cfg)
    else:
        s = {
            "embed": embed_schema(cfg.vocab_size, cfg.d_model),
            "blocks": _decoder_blocks_schema(cfg, cfg.num_layers),
            "final_norm": norm_schema(cfg.d_model, cfg.norm),
        }
    if not _tied(cfg):
        s["lm_head"] = P((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=0.02)
    return s


def _tied(cfg: ModelConfig) -> bool:
    return cfg.name.startswith(("gemma", "whisper"))


def init_model(cfg: ModelConfig, seed=0, dtype=jnp.float32):
    return init_params(model_schema(cfg), jax.random.PRNGKey(seed), dtype)


def abstract_model(cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return abstract_params(model_schema(cfg), dtype)


# ------------------------------------------------------------ block bodies

def _attn_block(p, x, *, cfg, window, positions, rules, blockwise=True,
                mamba_state=None):
    # Megatron-SP: norm runs on the seq-sharded residual (16x cheaper),
    # one all-gather recovers the full sequence for the heads-sharded
    # attention interior, and the out-projection reduce-scatters back.
    h = apply_norm(p["ln1"], x, cfg.norm)
    h = constrain(h, ("batch", None, None), rules)   # SP all-gather
    q, k, v = attn.project_qkv(p["attn"], h, positions, cfg.rope_theta,
                               use_rope=cfg.family not in ("encdec",),
                               n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads)
    q = constrain(q, ("batch", None, "act_heads", None), rules)
    k = constrain(k, ("batch", None, "act_heads", None), rules)
    v = constrain(v, ("batch", None, "act_heads", None), rules)
    fn = attn.attend_blockwise if blockwise else attn.attend_full
    kwargs = ({"q_chunk": cfg.attn_q_chunk, "kv_chunk": cfg.attn_kv_chunk}
              if blockwise else {})

    def attend(q, k, v, window):
        return fn(q, k, v, q_positions=positions, k_positions=positions,
                  causal=True, window=window, softcap=cfg.attn_logit_softcap,
                  **kwargs)

    if cfg.attn_remat and cfg.remat_policy != "nothing":
        # Flash-style backward: never save the (S, S) probabilities — the
        # inner checkpoint recomputes them per chunk in the backward pass.
        # Redundant (a third recompute) when the whole block is already
        # rematted with nothing_saveable (§Perf iteration 4).
        attend = jax.checkpoint(
            attend, policy=jax.checkpoint_policies.nothing_saveable)
    o = attend(q, k, v, window)
    o = attn.out_proj(p["attn"], o).astype(x.dtype)
    if cfg.seq_parallel:
        o = constrain(o, ("batch", "act_seq", None), rules)  # SP reduce-scatter
    mstate = None
    if cfg.family == "hybrid":
        hm = apply_norm(p["ln_mamba"], x, cfg.norm)
        hm = constrain(hm, ("batch", None, None), rules)
        om, mstate = ssm.mamba_apply(p["mamba"], hm, mamba_state)
        if cfg.seq_parallel:
            om = constrain(om, ("batch", "act_seq", None), rules)
        o = (o + om) * 0.5
    x = x + o
    return x, (k, v, mstate)


def _ffn_block(p, x, *, cfg, rules):
    h = apply_norm(p["ln2"], x, cfg.norm)
    h = constrain(h, ("batch", None, None), rules)       # SP all-gather
    aux = 0.0
    if cfg.num_experts:
        y, aux = moe_lib.apply_moe(p["moe"], h, cfg, rules=rules)
        if cfg.dense_residual and "mlp" in p:
            y = y + _mlp_tp(p["mlp"], h, cfg, rules)
    else:
        y = _mlp_tp(p["mlp"], h, cfg, rules)
    if cfg.seq_parallel:
        y = constrain(y, ("batch", "act_seq", None), rules)  # SP reduce-scatter
    return x + y.astype(x.dtype), aux


def _mlp_tp(p, h, cfg, rules):
    """GLU/MLP with the hidden activations pinned ff-sharded (TP interior)."""
    if cfg.act != "silu":
        return apply_mlp(p, h, cfg.act)
    g = jax.nn.silu(jnp.einsum("...d,df->...f", h, p["wi_gate"]))
    u = jnp.einsum("...d,df->...f", h, p["wi_up"])
    gu = constrain(g * u, ("batch", None, "act_ff"), rules)
    return jnp.einsum("...f,fd->...d", gu, p["wo"])


def _maybe_remat(fn, cfg):
    if not cfg.remat:
        return fn
    policy = {
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "nothing": jax.checkpoint_policies.nothing_saveable,
    }[cfg.remat_policy]
    return jax.checkpoint(fn, policy=policy)


def _sp(x, rules, cfg=None):
    """Sequence-parallel residual stream: between blocks the (B, S, D)
    activations live seq-sharded over "model" (Megatron-SP), so the
    per-layer carries the backward scan saves shard 16x.  GSPMD inserts
    the all-gather before attention/MLP and the reduce-scatter after.
    Disabled per-config for recurrent families (EXPERIMENTS §Perf)."""
    if cfg is not None and not cfg.seq_parallel:
        return x
    return constrain(x, ("batch", "act_seq", None), rules)


# --------------------------------------------------------- train forwards

def _decoder_forward(params, tokens, cfg, rules):
    B, S = tokens.shape
    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", "seq", None), rules)
    positions = jnp.arange(S)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    def block(carry, xs):
        x, aux = carry
        bp, window = xs
        x = _sp(x, rules, cfg)
        x, _ = _attn_block(bp, x, cfg=cfg, window=window, positions=positions,
                           rules=rules)
        x, a = _ffn_block(bp, x, cfg=cfg, rules=rules)
        return (_sp(x, rules, cfg), aux + a), None

    (x, aux), _ = jax.lax.scan(
        _maybe_remat(block, cfg), (x, 0.0), (params["blocks"], windows)
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def _xlstm_forward(params, tokens, cfg, rules):
    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", "seq", None), rules)
    g = params["groups"]

    def m_block(x, bp):
        # no SP here: the chunked mLSTM reshapes the sequence axis inside
        # every block — a seq-sharded carry makes GSPMD re-gather per chunk
        # (measured 6x memory-term regression; EXPERIMENTS §Perf).
        h = apply_norm(bp["ln"], x, cfg.norm)
        o, _ = ssm.mlstm_apply(bp["mlstm"], h)
        return x + o, None

    def group(x, gp):
        x, _ = jax.lax.scan(
            _maybe_remat(m_block, cfg), x,
            {"ln": gp["m_ln"], "mlstm": gp["mlstm"]},
        )
        h = apply_norm(gp["s_ln"], x, cfg.norm)
        o, _ = ssm.slstm_apply(gp["slstm"], h)
        return x + o, None

    x, _ = jax.lax.scan(group, x, g)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, 0.0


def _encdec_forward(params, tokens, frame_embeddings, cfg, rules):
    d = cfg.d_model
    enc = frame_embeddings.astype(jnp.dtype(cfg.dtype))
    enc = enc + _sinusoid(enc.shape[1], d, enc.dtype)
    enc_pos = jnp.arange(enc.shape[1])

    def enc_block(x, bp):
        h = apply_norm(bp["ln1"], x, cfg.norm)
        q, k, v = attn.project_qkv(bp["attn"], h, enc_pos, use_rope=False,
                                   n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads)
        o = attn.attend_full(q, k, v, q_positions=enc_pos, k_positions=enc_pos,
                             causal=False)
        x = x + attn.out_proj(bp["attn"], o)
        h = apply_norm(bp["ln2"], x, cfg.norm)
        return x + apply_mlp(bp["mlp"], h, cfg.act), None

    enc, _ = jax.lax.scan(_maybe_remat(enc_block, cfg), enc, params["encoder"])
    enc = apply_norm(params["enc_final_norm"], enc, cfg.norm)

    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(x.shape[1], d, x.dtype)
    pos = jnp.arange(tokens.shape[1])

    def dec_block(x, bp):
        h = apply_norm(bp["ln1"], x, cfg.norm)
        q, k, v = attn.project_qkv(bp["attn"], h, pos, use_rope=False,
                                   n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads)
        o = attn.attend_blockwise(q, k, v, q_positions=pos, k_positions=pos,
                                  causal=True, q_chunk=cfg.attn_q_chunk,
                                  kv_chunk=cfg.attn_kv_chunk)
        x = x + attn.out_proj(bp["attn"], o)
        h = apply_norm(bp["ln_x"], x, cfg.norm)
        qx = attn.proj_heads(bp["xattn"]["wq"], h, cfg.num_heads)
        kx = attn.proj_heads(bp["xattn"]["wk"], enc, cfg.num_kv_heads)
        vx = attn.proj_heads(bp["xattn"]["wv"], enc, cfg.num_kv_heads)
        ox = attn.attend_full(qx, kx, vx, q_positions=pos, k_positions=enc_pos,
                              causal=False)
        x = x + attn.out_proj(bp["xattn"], ox)
        h = apply_norm(bp["ln2"], x, cfg.norm)
        return x + apply_mlp(bp["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(_maybe_remat(dec_block, cfg), x, params["decoder"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, 0.0


def _vlm_forward(params, tokens, image_embeddings, cfg, rules):
    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", "seq", None), rules)
    img = image_embeddings.astype(jnp.dtype(cfg.dtype))
    S = tokens.shape[1]
    positions = jnp.arange(S)
    img_pos = jnp.arange(img.shape[1])

    def self_block(x, bp):
        x, _ = _attn_block(bp, x, cfg=cfg, window=0, positions=positions,
                           rules=rules)
        x, _ = _ffn_block(bp, x, cfg=cfg, rules=rules)
        return _sp(x, rules), None

    def group(x, gp):
        x, _ = jax.lax.scan(_maybe_remat(self_block, cfg), x, gp["self"])
        cp = gp["cross"]
        h = apply_norm(cp["ln"], x, cfg.norm)
        qx = attn.proj_heads(cp["xattn"]["wq"], h, cfg.num_heads)
        kx = attn.proj_heads(cp["xattn"]["wk"], img, cfg.num_kv_heads)
        vx = attn.proj_heads(cp["xattn"]["wv"], img, cfg.num_kv_heads)
        ox = attn.attend_full(qx, kx, vx, q_positions=positions,
                              k_positions=img_pos, causal=False)
        x = x + jnp.tanh(cp["gate_attn"]) * attn.out_proj(cp["xattn"], ox)
        h = apply_norm(cp["ln_mlp"], x, cfg.norm)
        x = x + jnp.tanh(cp["gate_mlp"]) * apply_mlp(cp["mlp"], h, cfg.act)
        return x, None

    x, _ = jax.lax.scan(group, x, params["groups"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, 0.0


def _sinusoid(S, d, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)[None]


def forward_train(params, batch, cfg: ModelConfig, rules=None):
    """Final hidden states + aux loss for a train/prefill batch."""
    tokens = batch["tokens"]
    if cfg.family == "ssm":
        return _xlstm_forward(params, tokens, cfg, rules)
    if cfg.family == "encdec":
        return _encdec_forward(params, tokens, batch["frame_embeddings"], cfg, rules)
    if cfg.family == "vlm":
        return _vlm_forward(params, tokens, batch["image_embeddings"], cfg, rules)
    return _decoder_forward(params, tokens, cfg, rules)


def _head_table(params, cfg):
    return params.get("lm_head", params["embed"]["table"])


def loss_fn(params, batch, cfg: ModelConfig, rules=None):
    h, aux = forward_train(params, batch, cfg, rules)
    nll = chunked_xent(h, _head_table(params, cfg), batch["labels"],
                       cfg.loss_chunk, cfg.final_logit_softcap)
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}


# ------------------------------------------------------------- decode path

def _cache_dtypes(cfg):
    return jnp.dtype(cfg.kv_cache_dtype), cfg.kv_cache_dtype == "int8"


def _layer_cache_struct(cfg, L, batch, max_len, abstract):
    hd = cfg.resolved_head_dim
    kv_dt, quant = _cache_dtypes(cfg)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (
        lambda s, d: jnp.zeros(s, d))
    Ls = L if isinstance(L, tuple) else (L,)
    c = {
        "k": mk(Ls + (batch, max_len, cfg.num_kv_heads, hd), kv_dt),
        "v": mk(Ls + (batch, max_len, cfg.num_kv_heads, hd), kv_dt),
    }
    if quant:
        c["k_scale"] = mk(Ls + (batch, max_len, cfg.num_kv_heads), jnp.float32)
        c["v_scale"] = mk(Ls + (batch, max_len, cfg.num_kv_heads), jnp.float32)
    return c


def _cache_struct(cfg: ModelConfig, batch, max_len, abstract):
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (
        lambda s, d: jnp.zeros(s, d))
    if cfg.family == "ssm":
        g = cfg.slstm_every
        G = cfg.num_layers // g
        hd = cfg.d_model // cfg.num_heads
        c = {
            "mlstm": {
                "C": mk((G, g - 1, batch, cfg.num_heads, hd, hd), jnp.float32),
                "n": mk((G, g - 1, batch, cfg.num_heads, hd), jnp.float32),
                "m": mk((G, g - 1, batch, cfg.num_heads), jnp.float32),
            },
            "slstm": {
                "c": mk((G, batch, cfg.num_heads, hd), jnp.float32),
                "n": mk((G, batch, cfg.num_heads, hd), jnp.float32),
                "m": mk((G, batch, cfg.num_heads, hd), jnp.float32),
            },
        }
    elif cfg.family == "encdec":
        Ld = cfg.num_layers - cfg.encoder_layers
        enc_len = max_len // cfg.encoder_seq_divisor
        hd = cfg.resolved_head_dim
        c = {
            "self": _layer_cache_struct(cfg, Ld, batch, max_len, abstract),
            "cross_k": mk((Ld, batch, enc_len, cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
            "cross_v": mk((Ld, batch, enc_len, cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
            "enc_len": mk((), jnp.int32),
        }
    elif cfg.family == "vlm":
        k = cfg.cross_attn_every
        G = cfg.num_layers // k
        hd = cfg.resolved_head_dim
        c = {
            "self": _layer_cache_struct(cfg, (G, k - 1), batch, max_len, abstract),
            "cross_k": mk((G, batch, cfg.img_tokens, cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
            "cross_v": mk((G, batch, cfg.img_tokens, cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
        }
    else:
        c = _layer_cache_struct(cfg, cfg.num_layers, batch, max_len, abstract)
        if cfg.family == "hybrid":
            di = cfg.mamba_expand * cfg.d_model
            c["mamba"] = {
                "ssm": mk((cfg.num_layers, batch, di, cfg.ssm_state), jnp.float32),
                "conv": mk((cfg.num_layers, batch, cfg.mamba_conv - 1, di), jnp.float32),
            }
    c["pos"] = mk((), jnp.int32)
    return c


def abstract_cache(cfg, batch, max_len):
    return _cache_struct(cfg, batch, max_len, abstract=True)


def init_decode_cache(cfg, batch, max_len):
    return _cache_struct(cfg, batch, max_len, abstract=False)


def _decode_attn_layer(bp, cache_l, x, *, cfg, window, pos, rules):
    """One decoder layer, single-token decode. Returns (x, new_cache_l)."""
    _, quant = _cache_dtypes(cfg)
    h = apply_norm(bp["ln1"], x, cfg.norm)
    q, k, v = attn.project_qkv(bp["attn"], h, pos[None], cfg.rope_theta,
                               n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads)
    # flash-decoding: q is tiny (one token) — replicate it over "model" so
    # GSPMD keeps the KV cache length-sharded and computes partial softmax
    # per shard, instead of all-gathering the cache to align with q's head
    # sharding (§Perf decode iteration: 52.6 GB/step of AG -> stat-sized).
    q = constrain(q, ("batch", None, None, None), rules)
    new_cache = attn.update_cache(cache_l, k, v, pos, quant)
    kc, vc = attn.read_cache(new_cache, jnp.dtype(cfg.dtype))
    o = attn.decode_attend(q, kc, vc, q_pos=pos, cache_len=pos + 1,
                           window=window, softcap=cfg.attn_logit_softcap)
    o = attn.out_proj(bp["attn"], o)
    if cfg.family == "hybrid":
        hm = apply_norm(bp["ln_mamba"], x, cfg.norm)
        om, mstate = ssm.mamba_decode(bp["mamba"], hm, cache_l["mamba"])
        o = (o + om) * 0.5
        new_cache["mamba"] = mstate
    x = x + o
    x, _ = _ffn_block(bp, x, cfg=cfg, rules=rules)
    return x, new_cache


def decode_step(params, cache, tokens, cfg: ModelConfig, rules=None):
    """One serve step: (B, 1) new tokens vs the cache. Returns (logits, cache)."""
    pos = cache["pos"]
    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    if cfg.family == "ssm":
        x, new_cache = _xlstm_decode(params, cache, x, cfg)
    elif cfg.family == "encdec":
        x, new_cache = _encdec_decode(params, cache, x, cfg, pos, rules)
    elif cfg.family == "vlm":
        x, new_cache = _vlm_decode(params, cache, x, cfg, pos, rules)
    else:
        layer_cache = {k: v for k, v in cache.items() if k != "pos"}
        if cfg.family == "hybrid":
            mamba = layer_cache.pop("mamba")
            layer_cache = dict(layer_cache, mamba=mamba)

        def block(x, xs):
            bp, cl, window = xs
            x, ncl = _decode_attn_layer(bp, cl, x, cfg=cfg, window=window,
                                        pos=pos, rules=rules)
            return x, ncl

        x, new_cache = jax.lax.scan(block, x, (params["blocks"], layer_cache, windows))
        x = apply_norm(params["final_norm"], x, cfg.norm)

    new_cache["pos"] = pos + 1
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32),
        _head_table(params, cfg).astype(jnp.float32),
    )
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    return logits, new_cache


def _xlstm_decode(params, cache, x, cfg):
    def m_block(x, xs):
        bp, st = xs
        h = apply_norm(bp["ln"], x, cfg.norm)
        o, st = ssm.mlstm_decode(bp["mlstm"], h, st)
        return x + o, st

    def group(x, xs):
        gp, mc, sc = xs
        x, m_new = jax.lax.scan(
            m_block, x, ({"ln": gp["m_ln"], "mlstm": gp["mlstm"]}, mc)
        )
        h = apply_norm(gp["s_ln"], x, cfg.norm)
        o, s_new = ssm.slstm_decode(gp["slstm"], h, sc)
        return x + o, (m_new, s_new)

    x, (m_new, s_new) = jax.lax.scan(
        group, x, (params["groups"], cache["mlstm"], cache["slstm"])
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, {"mlstm": m_new, "slstm": s_new}


def _encdec_decode(params, cache, x, cfg, pos, rules):
    x = x + _sinusoid_at(pos, cfg.d_model, x.dtype)
    _, quant = _cache_dtypes(cfg)

    def block(x, xs):
        bp, cl, ck, cv = xs
        h = apply_norm(bp["ln1"], x, cfg.norm)
        q, k, v = attn.project_qkv(bp["attn"], h, pos[None], use_rope=False,
                                   n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads)
        ncl = attn.update_cache(cl, k, v, pos, quant)
        kc, vc = attn.read_cache(ncl, jnp.dtype(cfg.dtype))
        o = attn.decode_attend(q, kc, vc, q_pos=pos, cache_len=pos + 1)
        x = x + attn.out_proj(bp["attn"], o)
        h = apply_norm(bp["ln_x"], x, cfg.norm)
        qx = attn.proj_heads(bp["xattn"]["wq"], h, cfg.num_heads)
        ox = attn.decode_attend(qx, ck.astype(x.dtype), cv.astype(x.dtype),
                                q_pos=jnp.asarray(2**30),
                                cache_len=cache["enc_len"])
        x = x + attn.out_proj(bp["xattn"], ox)
        h = apply_norm(bp["ln2"], x, cfg.norm)
        x = x + apply_mlp(bp["mlp"], h, cfg.act)
        return x, ncl

    x, self_new = jax.lax.scan(
        block, x,
        (params["decoder"], cache["self"], cache["cross_k"], cache["cross_v"]),
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, dict(cache, self=self_new)


def _vlm_decode(params, cache, x, cfg, pos, rules):
    def self_block(x, xs):
        bp, cl = xs
        x, ncl = _decode_attn_layer(bp, cl, x, cfg=cfg, window=0, pos=pos,
                                    rules=rules)
        return x, ncl

    def group(x, xs):
        gp, cl, ck, cv = xs
        x, ncl = jax.lax.scan(self_block, x, (gp["self"], cl))
        cp = gp["cross"]
        h = apply_norm(cp["ln"], x, cfg.norm)
        qx = attn.proj_heads(cp["xattn"]["wq"], h, cfg.num_heads)
        ox = attn.decode_attend(qx, ck.astype(x.dtype), cv.astype(x.dtype),
                                q_pos=jnp.asarray(2**30), cache_len=ck.shape[1])
        x = x + jnp.tanh(cp["gate_attn"]) * attn.out_proj(cp["xattn"], ox)
        h = apply_norm(cp["ln_mlp"], x, cfg.norm)
        x = x + jnp.tanh(cp["gate_mlp"]) * apply_mlp(cp["mlp"], h, cfg.act)
        return x, ncl

    x, self_new = jax.lax.scan(
        group, x, (params["groups"], cache["self"], cache["cross_k"], cache["cross_v"])
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, dict(cache, self=self_new)


def _sinusoid_at(pos, d, dtype):
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(dtype)


# ----------------------------------------------------------------- prefill

def prefill(params, batch, cfg: ModelConfig, max_len=None, rules=None):
    """Process a full prompt; returns (last-position logits, populated cache).

    Uses the train forward for hidden states plus a second pass collecting
    K/V per layer (keeps the scan structures identical; XLA CSEs the shared
    projections).  For the dry-run cells this is lowered as one XLA program.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    h, _ = forward_train(params, batch, cfg, rules)
    logits = jnp.einsum(
        "bd,vd->bv", h[:, -1].astype(jnp.float32),
        _head_table(params, cfg).astype(jnp.float32),
    )
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    cache = _prefill_cache(params, batch, cfg, max_len, rules)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, cache


def _prefill_cache(params, batch, cfg, max_len, rules):
    tokens = batch["tokens"]
    B, S = tokens.shape
    _, quant = _cache_dtypes(cfg)
    if cfg.family == "ssm":
        x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))

        def m_block(x, bp):
            h = apply_norm(bp["ln"], x, cfg.norm)
            hd = cfg.d_model // cfg.num_heads
            o, st = ssm.mlstm_apply(bp["mlstm"], h,
                                    ssm.mlstm_init_state(B, cfg.num_heads, hd))
            return x + o, st

        def group(x, gp):
            x, m_st = jax.lax.scan(m_block, x, {"ln": gp["m_ln"], "mlstm": gp["mlstm"]})
            h = apply_norm(gp["s_ln"], x, cfg.norm)
            hd = cfg.d_model // cfg.num_heads
            o, s_st = ssm.slstm_apply(gp["slstm"], h,
                                      ssm.slstm_init_state(B, cfg.num_heads, hd))
            return x + o, (m_st, s_st)

        _, (m_st, s_st) = jax.lax.scan(group, x, params["groups"])
        return {"mlstm": m_st, "slstm": s_st}

    # attention families: collect K/V per layer and pack into cache arrays
    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(S)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    if cfg.family in ("dense", "moe", "hybrid"):
        empty = init_decode_cache(cfg, B, max_len)

        def block(x, xs):
            bp, window, cl = xs
            ms = cl.get("mamba")
            x, (k, v, mstate) = _attn_block(
                bp, x, cfg=cfg, window=window, positions=positions,
                rules=rules, mamba_state=ms,
            )
            x, _ = _ffn_block(bp, x, cfg=cfg, rules=rules)
            ncl = attn.update_cache(
                {kk: vv for kk, vv in cl.items() if kk != "mamba"}, k, v, 0, quant
            )
            if mstate is not None:
                ncl["mamba"] = mstate
            return x, ncl

        layer_cache = {k: v for k, v in empty.items() if k != "pos"}
        _, new_cache = jax.lax.scan(block, x, (params["blocks"], windows, layer_cache))
        return new_cache

    if cfg.family == "encdec":
        return _encdec_prefill_cache(params, batch, cfg, max_len, rules, quant)
    if cfg.family == "vlm":
        return _vlm_prefill_cache(params, batch, cfg, max_len, rules, quant)
    raise NotImplementedError(f"prefill for family {cfg.family}")


def _encdec_prefill_cache(params, batch, cfg, max_len, rules, quant):
    tokens = batch["tokens"]
    B, S = tokens.shape
    d = cfg.d_model
    enc = batch["frame_embeddings"].astype(jnp.dtype(cfg.dtype))
    enc = enc + _sinusoid(enc.shape[1], d, enc.dtype)
    enc_pos = jnp.arange(enc.shape[1])

    def enc_block(x, bp):
        h = apply_norm(bp["ln1"], x, cfg.norm)
        q, k, v = attn.project_qkv(bp["attn"], h, enc_pos, use_rope=False,
                                   n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads)
        o = attn.attend_full(q, k, v, q_positions=enc_pos, k_positions=enc_pos,
                             causal=False)
        x = x + attn.out_proj(bp["attn"], o)
        h = apply_norm(bp["ln2"], x, cfg.norm)
        return x + apply_mlp(bp["mlp"], h, cfg.act), None

    enc, _ = jax.lax.scan(enc_block, enc, params["encoder"])
    enc = apply_norm(params["enc_final_norm"], enc, cfg.norm)

    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(S, d, x.dtype)
    pos = jnp.arange(S)
    empty = init_decode_cache(cfg, B, max_len)

    def dec_block(x, xs):
        bp, cl = xs
        h = apply_norm(bp["ln1"], x, cfg.norm)
        q, k, v = attn.project_qkv(bp["attn"], h, pos, use_rope=False,
                                   n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads)
        o = attn.attend_blockwise(q, k, v, q_positions=pos, k_positions=pos,
                                  causal=True, q_chunk=cfg.attn_q_chunk,
                                  kv_chunk=cfg.attn_kv_chunk)
        x = x + attn.out_proj(bp["attn"], o)
        h = apply_norm(bp["ln_x"], x, cfg.norm)
        qx = attn.proj_heads(bp["xattn"]["wq"], h, cfg.num_heads)
        kx = attn.proj_heads(bp["xattn"]["wk"], enc, cfg.num_kv_heads)
        vx = attn.proj_heads(bp["xattn"]["wv"], enc, cfg.num_kv_heads)
        ox = attn.attend_full(qx, kx, vx, q_positions=pos,
                              k_positions=enc_pos, causal=False)
        x = x + attn.out_proj(bp["xattn"], ox)
        h = apply_norm(bp["ln2"], x, cfg.norm)
        x = x + apply_mlp(bp["mlp"], h, cfg.act)
        ncl = attn.update_cache(cl, k, v, 0, quant)
        dt = jnp.dtype(cfg.dtype)
        return x, (ncl, kx.astype(dt), vx.astype(dt))

    _, (self_new, cross_k, cross_v) = jax.lax.scan(
        dec_block, x, (params["decoder"], empty["self"]))
    # cross arrays are sized for max_len//divisor; pad the computed ones
    enc_len = jnp.asarray(cross_k.shape[2], jnp.int32)
    pad = empty["cross_k"].shape[2] - cross_k.shape[2]
    if pad > 0:
        cross_k = jnp.pad(cross_k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cross_v = jnp.pad(cross_v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return {"self": self_new, "cross_k": cross_k, "cross_v": cross_v,
            "enc_len": enc_len}


def _vlm_prefill_cache(params, batch, cfg, max_len, rules, quant):
    tokens = batch["tokens"]
    B, S = tokens.shape
    img = batch["image_embeddings"].astype(jnp.dtype(cfg.dtype))
    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", "seq", None), rules)
    positions = jnp.arange(S)
    img_pos = jnp.arange(img.shape[1])
    empty = init_decode_cache(cfg, B, max_len)

    def self_block(x, xs):
        bp, cl = xs
        x, (k, v, _) = _attn_block(bp, x, cfg=cfg, window=0,
                                   positions=positions, rules=rules)
        x, _ = _ffn_block(bp, x, cfg=cfg, rules=rules)
        return x, attn.update_cache(cl, k, v, 0, quant)

    def group(x, xs):
        gp, cl = xs
        x, ncl = jax.lax.scan(self_block, x, (gp["self"], cl))
        cp = gp["cross"]
        h = apply_norm(cp["ln"], x, cfg.norm)
        qx = attn.proj_heads(cp["xattn"]["wq"], h, cfg.num_heads)
        kx = attn.proj_heads(cp["xattn"]["wk"], img, cfg.num_kv_heads)
        vx = attn.proj_heads(cp["xattn"]["wv"], img, cfg.num_kv_heads)
        ox = attn.attend_full(qx, kx, vx, q_positions=positions,
                              k_positions=img_pos, causal=False)
        x = x + jnp.tanh(cp["gate_attn"]) * attn.out_proj(cp["xattn"], ox)
        h = apply_norm(cp["ln_mlp"], x, cfg.norm)
        x = x + jnp.tanh(cp["gate_mlp"]) * apply_mlp(cp["mlp"], h, cfg.act)
        dt = jnp.dtype(cfg.dtype)
        return x, (ncl, kx.astype(dt), vx.astype(dt))

    _, (self_new, cross_k, cross_v) = jax.lax.scan(
        group, x, (params["groups"], empty["self"]))
    return {"self": self_new, "cross_k": cross_k, "cross_v": cross_v}


# ------------------------------------------------------- partition specs

def cache_partition_specs(cfg: ModelConfig, rules):
    """PartitionSpec tree mirroring ``abstract_cache`` (DESIGN.md §5)."""
    from jax.sharding import PartitionSpec as PS

    b = rules.get("batch")
    kv = rules.get("kv_len")
    fm = rules.get("act_ff")

    def kv_spec(lead_n):
        # KV sequence axis carries the model-parallel split (always divisible,
        # unlike head counts); heads stay replicated within a shard.
        lead = (None,) * lead_n
        s = {
            "k": PS(*lead, b, kv, None, None),
            "v": PS(*lead, b, kv, None, None),
        }
        if cfg.kv_cache_dtype == "int8":
            s["k_scale"] = PS(*lead, b, kv, None)
            s["v_scale"] = PS(*lead, b, kv, None)
        return s

    if cfg.family == "ssm":
        c = {
            "mlstm": {
                "C": PS(None, None, b, None, fm, None),
                "n": PS(None, None, b, None, fm),
                "m": PS(None, None, b, None),
            },
            "slstm": {
                "c": PS(None, b, None, fm),
                "n": PS(None, b, None, fm),
                "m": PS(None, b, None, fm),
            },
        }
    elif cfg.family == "encdec":
        c = {
            "self": kv_spec(1),
            "cross_k": PS(None, b, None, None, None),
            "cross_v": PS(None, b, None, None, None),
            "enc_len": PS(),
        }
    elif cfg.family == "vlm":
        c = {
            "self": kv_spec(2),
            "cross_k": PS(None, b, None, None, None),
            "cross_v": PS(None, b, None, None, None),
        }
    else:
        c = kv_spec(1)
        if cfg.family == "hybrid":
            c["mamba"] = {
                "ssm": PS(None, b, fm, None),
                "conv": PS(None, b, None, fm),
            }
    c["pos"] = PS()
    return c


def batch_partition_specs(cfg: ModelConfig, shape_kind, rules):
    from jax.sharding import PartitionSpec as PS

    b = rules.get("batch")
    sq = rules.get("seq")
    specs = {"tokens": PS(b, sq if shape_kind != "decode" else None)}
    if shape_kind == "train":
        specs["labels"] = PS(b, sq)
    if cfg.family == "encdec":
        specs["frame_embeddings"] = PS(b, None, None)
    if cfg.family == "vlm":
        specs["image_embeddings"] = PS(b, None, None)
    return specs
