"""Matrix-form BSI Pallas kernel: one MXU matmul per tile block.

Wu & Zou ("Matrix representation and GPU-optimized parallel B-spline
computing") recast uniform B-spline evaluation as small dense matrix
products.  On the aligned grid the three per-axis ``(d, 4)`` LUTs collapse
into one ``(dx*dy*dz, 64)`` Kronecker basis ``B`` (precomputed once per
(tile, dtype), :func:`repro.core.bspline.basis_matrix`), and a whole tile
block evaluates as a single contraction

    out[v, (t, ch)] = sum_k B[v, k] * win[k, (t, ch)]

— a ``(d^3, 64) @ (64, tiles*C)`` ``dot_general`` that Mosaic places on the
MXU with fp32 accumulation (``preferred_element_type``), so bf16 control
grids keep bf16 operand traffic but fp32 partial sums.  Where the other
kernels stream gathers and elementwise FMAs through the VPU, this mode
feeds the matrix units the registration hot loop otherwise leaves idle.

``contract_window``/``kron_basis`` are shared with the fused level-step
megakernel (``bsi_fused.py``), whose displacement stage can run this same
contraction behind its ``disp_form`` flag.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

__all__ = ["bsi_matmul_pallas", "contract_window", "kron_basis"]


def kron_basis(wx, wy, wz):
    """Build the ``(dx*dy*dz, 64)`` Kronecker basis from per-axis LUTs.

    In-kernel equivalent of ``repro.core.bspline.basis_matrix`` (tiny:
    ``64 * d^3`` elements), used by the fused kernel so its operand
    interface stays the three ``(d, 4)`` LUT refs every other stage shares.
    """
    dx, dy, dz = wx.shape[0], wy.shape[0], wz.shape[0]
    b = (wx.reshape(dx, 1, 1, 4, 1, 1)
         * wy.reshape(1, dy, 1, 1, 4, 1)
         * wz.reshape(1, 1, dz, 1, 1, 4))
    return b.reshape(dx * dy * dz, 64)


def contract_window(win, b, tile, block_tiles):
    """Evaluate a halo window as one MXU contraction against the basis.

    ``win`` is this grid cell's ``(bx+3, by+3, bz+3, C)`` control window,
    ``b`` the ``(dx*dy*dz, 64)`` basis.  The 64 ``(l, m, n)`` shifts of the
    window become the column matrix (the per-tile 4x4x4 support, laid out
    so channels are contiguous), one ``dot_general`` contracts them, and
    the ``(voxel-offset, tile)`` axes interleave back into the
    ``(bx*dx, by*dy, bz*dz, C)`` fp32 output block.
    """
    dx, dy, dz = tile
    bx, by, bz = block_tiles
    c = win.shape[-1]
    cols = jnp.stack([
        win[l : l + bx, m : m + by, n : n + bz].reshape(-1)
        for l in range(4) for m in range(4) for n in range(4)
    ])  # (64, bx*by*bz*C)
    h = jax.lax.dot_general(
        b, cols, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (dx*dy*dz, bx*by*bz*C)
    h = h.reshape(dx, dy, dz, bx, by, bz, c)
    h = h.transpose(3, 0, 4, 1, 5, 2, 6)
    return h.reshape(bx * dx, by * dy, bz * dz, c)


def _kernel(b_ref, phi_ref, out_ref, *, tile, block_tiles):
    win = common.phi_window(phi_ref, block_tiles)  # (bx+3, by+3, bz+3, C)
    out = contract_window(win, b_ref[...], tile, block_tiles)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "block_tiles", "interpret"))
def bsi_matmul_pallas(phi, b, *, tile, block_tiles, interpret=True):
    tx, ty, tz = (int(n) - 3 for n in phi.shape[:3])
    c = phi.shape[3]
    bx, by, bz = block_tiles
    assert tx % bx == 0 and ty % by == 0 and tz % bz == 0, (phi.shape, block_tiles)
    grid = (tx // bx, ty // by, tz // bz)
    out_shape = jax.ShapeDtypeStruct(
        (tx * tile[0], ty * tile[1], tz * tile[2], c), phi.dtype
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, block_tiles=block_tiles),
        grid=grid,
        in_specs=[
            common.lut_spec(b.shape),
            common.full_grid_spec(phi.shape),
        ],
        out_specs=common.out_spec(block_tiles, tile, c),
        out_shape=out_shape,
        interpret=interpret,
    )(b, phi)
