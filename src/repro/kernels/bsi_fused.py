"""Fused level-step Pallas kernel: BSI + warp + similarity in one VMEM pass.

The paper's thesis is that B-spline interpolation is memory-bound — wins come
from "minimizing the data that needs to be moved between memory and
processing cores".  The unfused level step moves a lot: it writes the dense
``(X, Y, Z, 3)`` displacement field to HBM, reads it back to warp, writes the
``(X, Y, Z)`` warped volume, and reads *that* back for the similarity
reduction.  This kernel does all three stages per tile-block while the data
is still in VMEM:

* the control grid is pinned in VMEM (one HBM load total, as in the forward
  kernels) and each Pallas grid cell evaluates its block's displacement with
  the separable sweeps of ``bsi_separable``;
* the moving and fixed volumes are pinned in VMEM too, so the warp is a
  VMEM gather at ``identity + displacement`` (clamped trilinear — exactly
  ``core.ffd.warp_volume``'s sampling);
* the similarity is accumulated as *partial sums* into one tiny output block
  shared by every grid cell (TPU grids execute sequentially, so first-cell
  init + accumulate is the standard Pallas reduction pattern): SSD / NCC
  moments, LNCC windowed moments via in-register box sums, and NMI as a
  fused Parzen joint-histogram — per block only a ``(block_voxels, bins)``
  temporary ever exists, never the ``(X*Y*Z, bins)`` HBM intermediate.

The dense field and the warped volume therefore never exist in HBM.  The
host-side combination of the partial sums into the scalar loss lives in
``kernels.ops.fused_similarity_loss``; the differentiable wrapper (custom
VJP via the analytic gather adjoint) is ``core.ffd.fused_warp_loss``.

Reductions run in two passes when the similarity needs global statistics of
the warped volume (NCC: its mean; NMI: its min/max for intensity
normalisation): pass one is the ``("stats",)`` variant below, pass two
consumes the resulting scalars.  Statistics of the *fixed* volume need no
kernel — fixed is a real HBM input, plain ``jnp`` reductions are already
single-pass.

Edge voxels: the dispatcher zero-pads the control grid and both volumes up
to whole blocks; out-of-volume voxels are masked out of every partial sum
(and LNCC masks to its VALID-window output positions), so padding never
changes the result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common
from repro.kernels.bsi_matmul import contract_window, kron_basis

__all__ = ["bsi_fused_pallas", "fused_out_shape", "SCALAR_LANES"]

# Width of the (1, SCALAR_LANES) rows used for scalar partial sums and for
# the host->kernel statistics operand (mean / min-max of the warped volume).
SCALAR_LANES = 8


def fused_out_shape(sim):
    """Partial-sum output shape for similarity spec ``sim`` (see ops)."""
    if sim[0] == "nmi":
        bins = int(sim[1])
        return (bins, bins)
    return (1, SCALAR_LANES)


def _disp_block(phi_ref, wx, wy, wz, *, tile, block_tiles, extra,
                form="separable"):
    """This cell's displacement block via the selected BSI contraction.

    ``form="separable"`` runs the contraction of ``bsi_separable._kernel``;
    ``form="matmul"`` runs ``bsi_matmul``'s single MXU contraction against
    the Kronecker basis (built in-kernel from the same three LUT refs — tiny
    at ``64 * d^3`` elements).  Either way the block is *extended* by
    ``extra`` tiles per axis (LNCC's window halo; zero elsewhere).  Returns
    float32 ``((bx+ex)*dx, (by+ey)*dy, (bz+ez)*dz, C)``.
    """
    dx, dy, dz = tile
    bx0, by0, bz0 = block_tiles
    bx, by, bz = (b + e for b, e in zip(block_tiles, extra))
    c = phi_ref.shape[-1]
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    win = phi_ref[pl.ds(i * bx0, bx + 3), pl.ds(j * by0, by + 3),
                  pl.ds(k * bz0, bz + 3), :]
    if form == "matmul":
        return contract_window(win, kron_basis(wx, wy, wz), tile,
                               (bx, by, bz))
    px = jnp.stack([win[l: l + bx] for l in range(4)])
    h = jax.lax.dot_general(
        wx, px.reshape(4, -1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(dx, bx, by + 3, bz + 3, c)
    h = jnp.moveaxis(h, 0, 1).reshape(bx * dx, by + 3, bz + 3, c)
    py = jnp.stack([h[:, m: m + by] for m in range(4)])
    h = jax.lax.dot_general(
        wy, py.reshape(4, -1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(dy, bx * dx, by, bz + 3, c)
    h = jnp.moveaxis(h, 0, 2).reshape(bx * dx, by * dy, bz + 3, c)
    pz = jnp.stack([h[:, :, n: n + bz] for n in range(4)])
    h = jax.lax.dot_general(
        wz, pz.reshape(4, -1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(dz, bx * dx, by * dy, bz, c)
    return jnp.moveaxis(h, 0, 3).reshape(bx * dx, by * dy, bz * dz, c)


def _warp_block(mov_ref, disp, *, base, vol_shape):
    """Trilinear-sample the VMEM moving volume at identity + displacement.

    Mirrors ``core.ffd.trilinear_sample``/``warp_volume``: fp32 coordinates,
    clamp-to-border, intensities in the moving volume's (compute) dtype with
    the lerp promoting to fp32.  Returns float32 ``(BX, BY, BZ)``.
    """
    X, Y, Z = vol_shape
    shape3 = disp.shape[:3]
    gx = jax.lax.broadcasted_iota(jnp.float32, shape3, 0) + base[0]
    gy = jax.lax.broadcasted_iota(jnp.float32, shape3, 1) + base[1]
    gz = jax.lax.broadcasted_iota(jnp.float32, shape3, 2) + base[2]
    cx = jnp.clip(gx + disp[..., 0], 0.0, X - 1.0)
    cy = jnp.clip(gy + disp[..., 1], 0.0, Y - 1.0)
    cz = jnp.clip(gz + disp[..., 2], 0.0, Z - 1.0)
    fx, fy, fz = jnp.floor(cx), jnp.floor(cy), jnp.floor(cz)
    tx, ty, tz = cx - fx, cy - fy, cz - fz
    x0 = fx.astype(jnp.int32)
    y0 = fy.astype(jnp.int32)
    z0 = fz.astype(jnp.int32)
    x1 = jnp.minimum(x0 + 1, X - 1)
    y1 = jnp.minimum(y0 + 1, Y - 1)
    z1 = jnp.minimum(z0 + 1, Z - 1)
    mov = mov_ref[...]
    c00 = mov[x0, y0, z0] * (1 - tx) + mov[x1, y0, z0] * tx
    c01 = mov[x0, y0, z1] * (1 - tx) + mov[x1, y0, z1] * tx
    c10 = mov[x0, y1, z0] * (1 - tx) + mov[x1, y1, z0] * tx
    c11 = mov[x0, y1, z1] * (1 - tx) + mov[x1, y1, z1] * tx
    c0 = c00 * (1 - ty) + c10 * ty
    c1 = c01 * (1 - ty) + c11 * ty
    return (c0 * (1 - tz) + c1 * tz).astype(jnp.float32)


def _box_sum(x, size):
    """VALID box *sum* over all three axes (LNCC's windowed moments)."""
    for ax in range(3):
        n = x.shape[ax] - size + 1
        acc = jax.lax.slice_in_dim(x, 0, n, axis=ax)
        for a in range(1, size):
            acc = acc + jax.lax.slice_in_dim(x, a, a + n, axis=ax)
        x = acc
    return x


def _scalar_row(*vals):
    """Pack partial-sum scalars into one (1, SCALAR_LANES) row."""
    row = list(vals) + [jnp.float32(0.0)] * (SCALAR_LANES - len(vals))
    return jnp.stack(row).reshape(1, SCALAR_LANES)


def _fused_kernel(wx_ref, wy_ref, wz_ref, sc_ref, phi_ref, mov_ref, fix_ref,
                  out_ref, *, tile, block_tiles, extra, vol_shape, sim,
                  disp_form="separable"):
    X, Y, Z = vol_shape
    dx, dy, dz = tile
    first = ((pl.program_id(0) == 0) & (pl.program_id(1) == 0)
             & (pl.program_id(2) == 0))
    base = (pl.program_id(0) * (block_tiles[0] * dx),
            pl.program_id(1) * (block_tiles[1] * dy),
            pl.program_id(2) * (block_tiles[2] * dz))

    h = _disp_block(phi_ref, wx_ref[...], wy_ref[...], wz_ref[...],
                    tile=tile, block_tiles=block_tiles, extra=extra,
                    form=disp_form)
    # quantise to the compute dtype (what the unfused path stores to HBM),
    # then sample with fp32 coordinates exactly as warp_volume does
    disp = h.astype(phi_ref.dtype).astype(jnp.float32)
    w = _warp_block(mov_ref, disp, base=base, vol_shape=vol_shape)

    shape3 = w.shape
    ix = jax.lax.broadcasted_iota(jnp.int32, shape3, 0) + base[0]
    iy = jax.lax.broadcasted_iota(jnp.int32, shape3, 1) + base[1]
    iz = jax.lax.broadcasted_iota(jnp.int32, shape3, 2) + base[2]
    valid = (ix < X) & (iy < Y) & (iz < Z)
    fb = fix_ref[pl.ds(base[0], shape3[0]), pl.ds(base[1], shape3[1]),
                 pl.ds(base[2], shape3[2])].astype(jnp.float32)

    kind = sim[0]
    if kind == "stats":
        part = _scalar_row(
            jnp.sum(jnp.where(valid, w, 0.0)),
            jnp.min(jnp.where(valid, w, jnp.inf)),
            jnp.max(jnp.where(valid, w, -jnp.inf)),
            jnp.sum(valid.astype(jnp.float32)),
        )

        @pl.when(first)
        def _():
            out_ref[...] = _scalar_row(
                jnp.float32(0.0), jnp.inf, -jnp.inf, jnp.float32(0.0))

        cur = out_ref[...]
        out_ref[...] = jnp.concatenate(
            [cur[:, 0:1] + part[:, 0:1],
             jnp.minimum(cur[:, 1:2], part[:, 1:2]),
             jnp.maximum(cur[:, 2:3], part[:, 2:3]),
             cur[:, 3:] + part[:, 3:]], axis=1)
        return

    if kind == "ssd":
        d2 = jnp.where(valid, (w - fb) ** 2, 0.0)
        part = _scalar_row(jnp.sum(d2), jnp.sum(valid.astype(jnp.float32)))
    elif kind == "ncc":
        mu_w = sc_ref[0, 0]
        mu_f = sc_ref[0, 1]
        a = jnp.where(valid, w - mu_w, 0.0)
        b = jnp.where(valid, fb - mu_f, 0.0)
        part = _scalar_row(jnp.sum(a * b), jnp.sum(a * a), jnp.sum(b * b))
    elif kind == "lncc":
        _, size, eps = sim
        inv = 1.0 / float(size) ** 3
        mu_w = _box_sum(w, size) * inv
        mu_f = _box_sum(fb, size) * inv
        var_w = _box_sum(w * w, size) * inv - mu_w**2
        var_f = _box_sum(fb * fb, size) * inv - mu_f**2
        cross = _box_sum(w * fb, size) * inv - mu_w * mu_f
        cc = cross**2 / (var_w * var_f + eps)
        # own positions [0, block) of this cell that are VALID-window
        # positions of the true volume; the halo recompute region and the
        # zero-padding contribute nothing
        rshape = cc.shape
        px = jax.lax.broadcasted_iota(jnp.int32, rshape, 0)
        py = jax.lax.broadcasted_iota(jnp.int32, rshape, 1)
        pz = jax.lax.broadcasted_iota(jnp.int32, rshape, 2)
        own = ((px < block_tiles[0] * dx) & (py < block_tiles[1] * dy)
               & (pz < block_tiles[2] * dz))
        own &= ((px + base[0] < X - size + 1) & (py + base[1] < Y - size + 1)
                & (pz + base[2] < Z - size + 1))
        cc = jnp.where(own, cc, 0.0)
        part = _scalar_row(jnp.sum(cc), jnp.sum(own.astype(jnp.float32)))
    elif kind == "nmi":
        _, bins, sigma_ratio, eps = sim
        lo_w, hi_w = sc_ref[0, 0], sc_ref[0, 1]
        lo_f, hi_f = sc_ref[0, 2], sc_ref[0, 3]
        an = ((w - lo_w) / jnp.maximum(hi_w - lo_w, 1e-8)).reshape(-1)
        bn = ((fb - lo_f) / jnp.maximum(hi_f - lo_f, 1e-8)).reshape(-1)
        centres = jnp.linspace(0.0, 1.0, bins, dtype=jnp.float32)
        sigma = sigma_ratio / (bins - 1)
        wa = jnp.exp(-0.5 * ((an[:, None] - centres[None, :]) / sigma) ** 2)
        wb = jnp.exp(-0.5 * ((bn[:, None] - centres[None, :]) / sigma) ** 2)
        wa = wa / (jnp.sum(wa, axis=1, keepdims=True) + eps)
        wb = wb / (jnp.sum(wb, axis=1, keepdims=True) + eps)
        wa = wa * valid.reshape(-1)[:, None]  # padding voxels: zero rows
        part = jax.lax.dot_general(  # (V, bins) x (V, bins) -> (bins, bins)
            wa, wb, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:  # pragma: no cover - dispatcher validates
        raise ValueError(f"no fused accumulator for similarity {kind!r}")

    @pl.when(first)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += part


@functools.partial(jax.jit, static_argnames=(
    "tile", "block_tiles", "extra", "vol_shape", "sim", "interpret",
    "disp_form"))
def bsi_fused_pallas(phi, mov, fix, wx, wy, wz, scalars, *, tile, block_tiles,
                     extra, vol_shape, sim, interpret=True,
                     disp_form="separable"):
    """Run the fused level-step kernel; returns the partial-sum block.

    ``phi``/``mov``/``fix`` arrive pre-padded to whole (extended) blocks from
    ``kernels.ops``; ``scalars`` is the ``(1, SCALAR_LANES)`` statistics row
    (zeros when ``sim`` needs none); ``sim`` is a similarity spec tuple
    (``("stats",) | ("ssd",) | ("ncc",) | ("lncc", size, eps) |
    ("nmi", bins, sigma_ratio, eps)``); ``disp_form`` picks the BSI
    contraction of the displacement stage (see :func:`_disp_block`).
    """
    bx, by, bz = block_tiles
    ex, ey, ez = extra
    dx, dy, dz = tile
    grid = ((phi.shape[0] - 3 - ex) // bx, (phi.shape[1] - 3 - ey) // by,
            (phi.shape[2] - 3 - ez) // bz)
    assert mov.shape == tuple(
        g * b * d + e * d
        for g, b, e, d in zip(grid, block_tiles, extra, tile)), (
            mov.shape, grid, block_tiles, extra, tile)
    out_shape = fused_out_shape(sim)
    return pl.pallas_call(
        functools.partial(_fused_kernel, tile=tile, block_tiles=block_tiles,
                          extra=extra, vol_shape=vol_shape, sim=sim,
                          disp_form=disp_form),
        grid=grid,
        in_specs=[
            common.lut_spec(wx.shape),
            common.lut_spec(wy.shape),
            common.lut_spec(wz.shape),
            common.lut_spec(scalars.shape),
            common.full_grid_spec(phi.shape),
            common.lut_spec(mov.shape),
            common.lut_spec(fix.shape),
        ],
        out_specs=pl.BlockSpec(out_shape, lambda i, j, k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=interpret,
    )(wx, wy, wz, scalars, phi, mov, fix)
