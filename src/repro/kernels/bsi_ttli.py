"""Thread-per-Tile + Linear Interpolations BSI Pallas kernel (paper §3.3).

The 64-term weighted sum is regrouped into staged pairwise lerps using the
partition-of-unity renormalisation (``repro.core.bspline.lerp_luts``):
63 lerps = 126 FMA-class ops per voxel vs 255 for the weighted sum
(paper App. B).  Each ``a + t*(b-a)`` maps to a fused multiply-add on the
TPU VPU — the accuracy benefit the paper measures in Tables 3/4.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from repro.kernels import common

__all__ = ["bsi_ttli_pallas"]


def _lerp(a, b, t):
    return a + t * (b - a)


def _kernel(lx_ref, ly_ref, lz_ref, phi_ref, out_ref, *, tile, block_tiles):
    dx, dy, dz = tile
    bx, by, bz = block_tiles
    c = out_ref.shape[-1]
    win = common.phi_window(phi_ref, block_tiles)  # (bx+3, by+3, bz+3, C)
    t0x, t1x, sx = lx_ref[0], lx_ref[1], lx_ref[2]
    t0y, t1y, sy = ly_ref[0], ly_ref[1], ly_ref[2]
    t0z, t1z, sz = lz_ref[0], lz_ref[1], lz_ref[2]

    # x stage: collapse the 4 x-neighbours with 3 lerps.
    f = [win[l : l + bx] for l in range(4)]
    r = lambda t: t[None, :, None, None, None]
    h = _lerp(
        _lerp(f[0][:, None], f[1][:, None], r(t0x)),
        _lerp(f[2][:, None], f[3][:, None], r(t1x)),
        r(sx),
    ).reshape(bx * dx, by + 3, bz + 3, c)
    # y stage
    f = [h[:, m : m + by] for m in range(4)]
    r = lambda t: t[None, None, :, None, None]
    h = _lerp(
        _lerp(f[0][:, :, None], f[1][:, :, None], r(t0y)),
        _lerp(f[2][:, :, None], f[3][:, :, None], r(t1y)),
        r(sy),
    ).reshape(bx * dx, by * dy, bz + 3, c)
    # z stage
    f = [h[:, :, n : n + bz] for n in range(4)]
    r = lambda t: t[None, None, None, :, None]
    h = _lerp(
        _lerp(f[0][:, :, :, None], f[1][:, :, :, None], r(t0z)),
        _lerp(f[2][:, :, :, None], f[3][:, :, :, None], r(t1z)),
        r(sz),
    )
    out_ref[...] = h.reshape(bx * dx, by * dy, bz * dz, c)


@functools.partial(jax.jit, static_argnames=("tile", "block_tiles", "interpret"))
def bsi_ttli_pallas(phi, lx, ly, lz, *, tile, block_tiles, interpret=True):
    """``lx/ly/lz``: stacked lerp LUTs ``(3, delta)`` = (t0, t1, s) per axis."""
    tx, ty, tz = (int(n) - 3 for n in phi.shape[:3])
    c = phi.shape[3]
    bx, by, bz = block_tiles
    assert tx % bx == 0 and ty % by == 0 and tz % bz == 0, (phi.shape, block_tiles)
    grid = (tx // bx, ty // by, tz // bz)
    out_shape = jax.ShapeDtypeStruct(
        (tx * tile[0], ty * tile[1], tz * tile[2], c), phi.dtype
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, block_tiles=block_tiles),
        grid=grid,
        in_specs=[
            common.lut_spec(lx.shape),
            common.lut_spec(ly.shape),
            common.lut_spec(lz.shape),
            common.full_grid_spec(phi.shape),
        ],
        out_specs=common.out_spec(block_tiles, tile, c),
        out_shape=out_shape,
        interpret=interpret,
    )(lx, ly, lz, phi)
