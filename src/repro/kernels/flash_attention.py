"""Fused flash attention Pallas kernel (TPU target, interpret-validated).

Motivated directly by the §Perf qwen1.5-32b finding: the jnp blockwise
attention writes every (q_chunk x kv_chunk) score tile to HBM (~20 TB per
train step per device); a fused kernel keeps scores in VMEM and brings
attention HBM traffic down to the q/k/v/o streams.

Grid: (batch*heads, q_blocks); the kv loop runs inside the kernel with
online-softmax carries held in VMEM.  Supports causal masking, sliding
windows (gemma local layers) and logit softcaps (gemma2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

NEG_INF = -2.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_seq, block_q, block_kv,
            causal, window, softcap, scale):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale      # (block_q, hd)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(start, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.ds(start * block_kv, block_kv), slice(None)))
        v = pl.load(v_ref, (pl.ds(start * block_kv, block_kv), slice(None)))
        s = q @ k.astype(jnp.float32).T             # (block_q, block_kv)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = start * block_kv + jax.lax.iota(jnp.int32, block_kv)
        ok = jnp.ones((block_q, block_kv), bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window:
            ok &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[:, None] + p @ v.astype(jnp.float32)
        return m_new, l, acc

    hd = q_ref.shape[-1]
    m0 = jnp.full((block_q,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, hd), jnp.float32)
    n_kv = kv_seq // block_kv
    if causal:  # skip fully-masked kv blocks beyond the diagonal
        n_kv_eff = jnp.minimum(
            n_kv, (qi + 1) * block_q // block_kv + 1)
    else:
        n_kv_eff = n_kv
    m, l, acc = jax.lax.fori_loop(0, n_kv_eff, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv",
                     "interpret"),
)
def flash_attention_pallas(q, k, v, *, causal=True, window=0, softcap=0.0,
                           block_q=128, block_kv=128, interpret=True):
    """q: (B, S, H, hd); k/v: (B, S, KV, hd).  Returns (B, S, H, hd).

    GQA handled by head-index mapping (no KV repetition in HBM).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    scale = 1.0 / (hd ** 0.5)

    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    grid = (B * H, S // block_q)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kv_seq=S, block_q=block_q, block_kv=block_kv,
            causal=causal, window=window, softcap=softcap, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda bh, qi: (bh, qi, 0)),
            # whole KV stream for this (batch, kv-head) stays addressable;
            # the kernel streams block_kv slices from it
            pl.BlockSpec((None, S, hd), lambda bh, qi, rep=rep: (bh // rep, 0, 0)),
            pl.BlockSpec((None, S, hd), lambda bh, qi, rep=rep: (bh // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
