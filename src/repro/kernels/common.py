"""Shared plumbing for the BSI Pallas kernels.

TPU mapping of the paper's Thread-per-Tile scheme (DESIGN.md §2):

* the control grid (small: ``vol/delta^3`` points) is VMEM-resident — one
  HBM->VMEM load total, the analogue of the paper's global->shared staging;
* each Pallas grid cell owns a *block of tiles* and reads its
  ``(bt+3)^3`` halo window from VMEM — the analogue of the paper's
  per-thread register tile, with the ``(4+l-1)(4+m-1)(4+n-1)`` overlap
  saving of paper Eq. (A.4);
* the dense output (the big array) is written exactly once, blocked.
"""
from __future__ import annotations

from jax.experimental import pallas as pl

__all__ = ["phi_window", "out_block_shape", "full_grid_spec", "lut_spec", "out_spec"]


def phi_window(phi_ref, block_tiles):
    """Slice this grid cell's (bt+3)^3 halo window out of the VMEM grid."""
    bx, by, bz = block_tiles
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    return phi_ref[
        pl.ds(i * bx, bx + 3), pl.ds(j * by, by + 3), pl.ds(k * bz, bz + 3), :
    ]


def out_block_shape(block_tiles, tile, channels):
    bx, by, bz = block_tiles
    dx, dy, dz = tile
    return (bx * dx, by * dy, bz * dz, channels)


def full_grid_spec(shape):
    """BlockSpec pinning the full control grid in VMEM for every grid cell."""
    return pl.BlockSpec(shape, lambda i, j, k: (0, 0, 0, 0))


def lut_spec(shape):
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, j, k: (0,) * nd)


def out_spec(block_tiles, tile, channels):
    return pl.BlockSpec(
        out_block_shape(block_tiles, tile, channels), lambda i, j, k: (i, j, k, 0)
    )
