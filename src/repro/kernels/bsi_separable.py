"""Separable (tensor-contraction) BSI Pallas kernel — beyond the paper.

The aligned-grid weighted sum is a Tucker contraction:

    out[a,b,c] = sum_{l,m,n} Wx[a,l] * Wy[b,m] * Wz[c,n] * phi[l,m,n]

so instead of 64 MACs per voxel (TT) or 63 lerps (TTLI), three per-axis
sweeps cost ``4 + 16/d + 64/d^2`` MACs per voxel — for the default 5^3 tile
**1220 MACs per 125-voxel tile vs 8000** (6.6x fewer FLOPs, ->16x as d grows).
Each sweep is a small ``dot_general`` that XLA/Mosaic places on the MXU.
This is the paper's operand-regrouping idea pushed to its limit on a
systolic-array machine (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

__all__ = ["bsi_separable_pallas"]


def _kernel(wx_ref, wy_ref, wz_ref, phi_ref, out_ref, *, tile, block_tiles):
    dx, dy, dz = tile
    bx, by, bz = block_tiles
    c = out_ref.shape[-1]
    win = common.phi_window(phi_ref, block_tiles)  # (bx+3, by+3, bz+3, C)
    wx = wx_ref[...]
    wy = wy_ref[...]
    wz = wz_ref[...]

    # x sweep: (4, bx, Y, Z, C) x (dx, 4) -> (bx, dx, Y, Z, C)
    px = jnp.stack([win[l : l + bx] for l in range(4)])
    h = jax.lax.dot_general(
        wx, px.reshape(4, -1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(dx, bx, by + 3, bz + 3, c)
    h = jnp.moveaxis(h, 0, 1).reshape(bx * dx, by + 3, bz + 3, c)
    # y sweep
    py = jnp.stack([h[:, m : m + by] for m in range(4)])
    h = jax.lax.dot_general(
        wy, py.reshape(4, -1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(dy, bx * dx, by, bz + 3, c)
    h = jnp.moveaxis(h, 0, 2).reshape(bx * dx, by * dy, bz + 3, c)
    # z sweep
    pz = jnp.stack([h[:, :, n : n + bz] for n in range(4)])
    h = jax.lax.dot_general(
        wz, pz.reshape(4, -1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(dz, bx * dx, by * dy, bz, c)
    h = jnp.moveaxis(h, 0, 3).reshape(bx * dx, by * dy, bz * dz, c)
    out_ref[...] = h.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "block_tiles", "interpret"))
def bsi_separable_pallas(phi, wx, wy, wz, *, tile, block_tiles, interpret=True):
    tx, ty, tz = (int(n) - 3 for n in phi.shape[:3])
    c = phi.shape[3]
    bx, by, bz = block_tiles
    assert tx % bx == 0 and ty % by == 0 and tz % bz == 0, (phi.shape, block_tiles)
    grid = (tx // bx, ty // by, tz // bz)
    out_shape = jax.ShapeDtypeStruct(
        (tx * tile[0], ty * tile[1], tz * tile[2], c), phi.dtype
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, block_tiles=block_tiles),
        grid=grid,
        in_specs=[
            common.lut_spec(wx.shape),
            common.lut_spec(wy.shape),
            common.lut_spec(wz.shape),
            common.full_grid_spec(phi.shape),
        ],
        out_specs=common.out_spec(block_tiles, tile, c),
        out_shape=out_shape,
        interpret=interpret,
    )(wx, wy, wz, phi)
