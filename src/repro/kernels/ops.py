"""Jit'd dispatch wrappers for the BSI Pallas kernels.

Handles the plumbing the kernels don't: LUT construction, padding the tile
count up to a block multiple (padded control points never reach the cropped
output), block-size selection under the VMEM budget, and z-chunking when a
control grid exceeds VMEM (the rare >16 MB grid case; the chunk halo is the
level-2 instance of the paper's Eq. A.4 overlap scheme).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bspline import lerp_luts, weight_lut
from repro.kernels.bsi_separable import bsi_separable_pallas
from repro.kernels.bsi_tt import bsi_tt_pallas
from repro.kernels.bsi_ttli import bsi_ttli_pallas

__all__ = ["PALLAS_MODES", "bsi_pallas", "default_interpret", "pick_block_tiles"]

# Modes with a Pallas kernel (``gather`` has none — it is the baseline the
# kernels beat).  The engine autotuner enumerates its candidates from this.
PALLAS_MODES = ("tt", "ttli", "separable")

# Budget for (control grid + out block + window temporaries) in VMEM.
_VMEM_BUDGET_BYTES = 12 * 2**20
_DEFAULT_BLOCK_TILES = (4, 4, 4)  # cubes maximise halo overlap (paper §3.4)


def pick_block_tiles(num_tiles, tile, channels, itemsize, budget=_VMEM_BUDGET_BYTES):
    """Pick a tile-block shape: cube-ish, bounded by the VMEM budget."""
    bt = list(_DEFAULT_BLOCK_TILES)
    while True:
        out_bytes = (
            bt[0] * tile[0] * bt[1] * tile[1] * bt[2] * tile[2] * channels * itemsize
        )
        win_bytes = (bt[0] + 3) * (bt[1] + 3) * (bt[2] + 3) * channels * itemsize
        if out_bytes + 8 * win_bytes < budget // 2 or max(bt) == 1:
            return tuple(bt)
        bt[bt.index(max(bt))] = max(1, max(bt) // 2)


def _pad_tiles(phi, num_tiles, block_tiles):
    pads = []
    for t, b in zip(num_tiles, block_tiles):
        pads.append((0, (-t) % b))
    pads.append((0, 0))
    if any(p[1] for p in pads):
        phi = jnp.pad(phi, pads)
    return phi, tuple(t + p[1] for t, p in zip(num_tiles, pads))


def default_interpret() -> bool:
    """Whether the kernels need ``interpret=True`` on the current backend.

    Pallas TPU kernels compile only on TPU; everywhere else (CPU CI, GPU
    hosts) they run under the interpreter.  Resolving this from
    ``jax.default_backend()`` lets callers leave ``interpret`` unset and
    still get compiled kernels on real hardware.
    """
    return jax.default_backend() != "tpu"


def bsi_pallas(phi, tile, *, mode="ttli", dtype=None, block_tiles=None,
               interpret=None):
    """Run one of the BSI Pallas kernels on a stored control grid.

    Args match ``repro.core.interpolate.interpolate``; ``mode`` selects the
    kernel (``tt`` | ``ttli`` | ``separable``; ``gather`` has no kernel — it
    is the baseline the kernels beat).  ``interpret`` defaults to
    :func:`default_interpret` — compiled on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    return _bsi_pallas_jit(phi, tile, mode=mode, dtype=dtype,
                           block_tiles=block_tiles, interpret=bool(interpret))


@functools.partial(
    jax.jit, static_argnames=("tile", "mode", "dtype", "block_tiles", "interpret")
)
def _bsi_pallas_jit(phi, tile, *, mode, dtype, block_tiles, interpret):
    if mode not in PALLAS_MODES:
        raise ValueError(f"no Pallas kernel for mode {mode!r}")
    if dtype is not None:
        phi = phi.astype(dtype)
    tile = tuple(int(t) for t in tile)
    num_tiles = tuple(int(n) - 3 for n in phi.shape[:3])
    c = phi.shape[3]
    if block_tiles is None:
        block_tiles = pick_block_tiles(num_tiles, tile, c, phi.dtype.itemsize)
    block_tiles = tuple(min(b, t) for b, t in zip(block_tiles, num_tiles))
    phi_p, padded_tiles = _pad_tiles(phi, num_tiles, block_tiles)

    if mode == "tt":
        luts = tuple(weight_lut(d, phi.dtype) for d in tile)
        out = bsi_tt_pallas(
            phi_p, *luts, tile=tile, block_tiles=block_tiles, interpret=interpret
        )
    elif mode == "ttli":
        luts = tuple(jnp.stack(lerp_luts(d, phi.dtype)) for d in tile)
        out = bsi_ttli_pallas(
            phi_p, *luts, tile=tile, block_tiles=block_tiles, interpret=interpret
        )
    elif mode == "separable":
        luts = tuple(weight_lut(d, phi.dtype) for d in tile)
        out = bsi_separable_pallas(
            phi_p, *luts, tile=tile, block_tiles=block_tiles, interpret=interpret
        )
    else:  # unreachable: PALLAS_MODES checked above; keep dispatch explicit
        raise ValueError(f"no Pallas kernel for mode {mode!r}")
    return out[
        : num_tiles[0] * tile[0], : num_tiles[1] * tile[1], : num_tiles[2] * tile[2]
    ]
