"""Jit'd dispatch wrappers for the BSI Pallas kernels.

Handles the plumbing the kernels don't: LUT construction, padding the tile
count up to a block multiple (padded control points never reach the cropped
output), block-size selection under the VMEM budget, and z-chunking when a
control grid exceeds VMEM (the rare >16 MB grid case; the chunk halo is the
level-2 instance of the paper's Eq. A.4 overlap scheme).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bspline import basis_matrix, lerp_luts, weight_lut
from repro.kernels.bsi_adjoint import (bsi_adjoint_matmul_pallas,
                                       bsi_adjoint_separable_pallas)
from repro.kernels.bsi_fused import SCALAR_LANES, bsi_fused_pallas
from repro.kernels.bsi_matmul import bsi_matmul_pallas
from repro.kernels.bsi_separable import bsi_separable_pallas
from repro.kernels.bsi_tt import bsi_tt_pallas
from repro.kernels.bsi_ttli import bsi_ttli_pallas

__all__ = ["PALLAS_MODES", "FUSED_SIM_KINDS", "bsi_pallas",
           "bsi_adjoint_pallas", "fused_similarity_loss", "fused_supported",
           "default_interpret", "pick_block_tiles"]

# Modes with a Pallas kernel (``gather`` has none — it is the baseline the
# kernels beat).  The engine autotuner enumerates its candidates from this.
PALLAS_MODES = ("tt", "ttli", "separable", "matmul")

# Budget for (control grid + out block + window temporaries) in VMEM.
_VMEM_BUDGET_BYTES = 12 * 2**20
_DEFAULT_BLOCK_TILES = (4, 4, 4)  # cubes maximise halo overlap (paper §3.4)


def _shrink_to_budget(limits, bytes_fn, budget):
    """Clamp the default block to ``limits``, then halve the largest axis
    until ``bytes_fn(block)`` fits half the budget (or every axis is 1).

    The clamp means tiny grids never budget for (and pad up to) blocks
    larger than the whole grid.  Shared by the forward (tile-block) and
    adjoint (control-point-block) pickers, which differ only in what the
    block's bytes are.
    """
    b = [min(d, max(1, int(n))) for d, n in zip(_DEFAULT_BLOCK_TILES, limits)]
    while bytes_fn(b) >= budget // 2 and max(b) > 1:
        b[b.index(max(b))] = max(1, max(b) // 2)
    return tuple(b)


def pick_block_tiles(num_tiles, tile, channels, itemsize, budget=_VMEM_BUDGET_BYTES):
    """Pick a tile-block shape: cube-ish, bounded by the VMEM budget."""

    def block_bytes(bt):
        out = bt[0] * tile[0] * bt[1] * tile[1] * bt[2] * tile[2]
        win = (bt[0] + 3) * (bt[1] + 3) * (bt[2] + 3)
        return (out + 8 * win) * channels * itemsize

    return _shrink_to_budget(num_tiles, block_bytes, budget)


def _pad_tiles(phi, num_tiles, block_tiles):
    pads = []
    for t, b in zip(num_tiles, block_tiles):
        pads.append((0, (-t) % b))
    pads.append((0, 0))
    if any(p[1] for p in pads):
        phi = jnp.pad(phi, pads)
    return phi, tuple(t + p[1] for t, p in zip(num_tiles, pads))


def default_interpret() -> bool:
    """Whether the kernels need ``interpret=True`` on the current backend.

    Pallas TPU kernels compile only on TPU; everywhere else (CPU CI, GPU
    hosts) they run under the interpreter.  Resolving this from
    ``jax.default_backend()`` lets callers leave ``interpret`` unset and
    still get compiled kernels on real hardware.
    """
    return jax.default_backend() != "tpu"


def bsi_pallas(phi, tile, *, mode="ttli", dtype=None, block_tiles=None,
               interpret=None):
    """Run one of the BSI Pallas kernels on a stored control grid.

    Args match ``repro.core.interpolate.interpolate``; ``mode`` selects the
    kernel (``tt`` | ``ttli`` | ``separable`` | ``matmul``; ``gather`` has
    no kernel — it is the baseline the kernels beat).  ``interpret``
    defaults to
    :func:`default_interpret` — compiled on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    return _bsi_pallas_jit(phi, tile, mode=mode, dtype=dtype,
                           block_tiles=block_tiles, interpret=bool(interpret))


@functools.partial(
    jax.jit, static_argnames=("tile", "mode", "dtype", "block_tiles", "interpret")
)
def _bsi_pallas_jit(phi, tile, *, mode, dtype, block_tiles, interpret):
    if mode not in PALLAS_MODES:
        raise ValueError(f"no Pallas kernel for mode {mode!r}")
    if dtype is not None:
        phi = phi.astype(dtype)
    tile = tuple(int(t) for t in tile)
    num_tiles = tuple(int(n) - 3 for n in phi.shape[:3])
    c = phi.shape[3]
    if block_tiles is None:
        block_tiles = pick_block_tiles(num_tiles, tile, c, phi.dtype.itemsize)
    block_tiles = tuple(min(b, t) for b, t in zip(block_tiles, num_tiles))
    phi_p, padded_tiles = _pad_tiles(phi, num_tiles, block_tiles)

    if mode == "tt":
        luts = tuple(weight_lut(d, phi.dtype) for d in tile)
        out = bsi_tt_pallas(
            phi_p, *luts, tile=tile, block_tiles=block_tiles, interpret=interpret
        )
    elif mode == "ttli":
        luts = tuple(jnp.stack(lerp_luts(d, phi.dtype)) for d in tile)
        out = bsi_ttli_pallas(
            phi_p, *luts, tile=tile, block_tiles=block_tiles, interpret=interpret
        )
    elif mode == "separable":
        luts = tuple(weight_lut(d, phi.dtype) for d in tile)
        out = bsi_separable_pallas(
            phi_p, *luts, tile=tile, block_tiles=block_tiles, interpret=interpret
        )
    elif mode == "matmul":
        b = basis_matrix(tile, phi.dtype)
        out = bsi_matmul_pallas(
            phi_p, b, tile=tile, block_tiles=block_tiles, interpret=interpret
        )
    else:  # unreachable: PALLAS_MODES checked above; keep dispatch explicit
        raise ValueError(f"no Pallas kernel for mode {mode!r}")
    return out[
        : num_tiles[0] * tile[0], : num_tiles[1] * tile[1], : num_tiles[2] * tile[2]
    ]


def pick_block_ctrl(num_ctrl, tile, channels, itemsize,
                    budget=_VMEM_BUDGET_BYTES):
    """Pick the adjoint kernel's control-point block: cube-ish, VMEM-bounded.

    The dominant temporary is the ``((bc+3)*d)^3`` cotangent window each grid
    cell reduces (read bf16/f32, accumulated f32), so the window is what the
    budget bounds (4x headroom for the sweep temporaries); the ``bc^3``
    output block is negligible next to it.
    """

    def block_bytes(bc):
        win = ((bc[0] + 3) * tile[0] * (bc[1] + 3) * tile[1]
               * (bc[2] + 3) * tile[2])
        return 4 * win * channels * itemsize

    return _shrink_to_budget(num_ctrl, block_bytes, budget)


def bsi_adjoint_pallas(g, tile, *, dtype=None, block_ctrl=None,
                       interpret=None, form="separable"):
    """Run the Pallas BSI adjoint: dense cotangent -> control-grid cotangent.

    The transpose of :func:`bsi_pallas` (same answer for every forward mode —
    BSI is linear, all modes compute the same function).  ``g`` is the
    ``(Tx*dx, Ty*dy, Tz*dz, C)`` cotangent of the dense field; returns the
    ``(Tx+3, Ty+3, Tz+3, C)`` control-grid cotangent in ``dtype`` (default
    float32 — fp32 accumulation even for bf16 cotangents).  ``interpret``
    defaults to :func:`default_interpret`.  ``form`` picks the per-block
    reduction: ``separable`` (three per-axis sweeps, ``grad_impl="pallas"``)
    or ``matmul`` (one transposed MXU contraction, ``grad_impl="matmul"``).

    The dispatcher zero-pads ``g`` by 3 tiles per axis so every control
    point uniformly owns the padded-tile window ``[i, i+4)`` (the adjoint
    mirror of the forward halo), pads the control count up to a block
    multiple, and z-chunks the padded cotangent when it exceeds the VMEM
    budget (the level-2 Eq. A.4 overlap scheme, on the gradient).
    """
    if interpret is None:
        interpret = default_interpret()
    return _bsi_adjoint_jit(g, tuple(int(t) for t in tile), dtype=dtype,
                            block_ctrl=block_ctrl, interpret=bool(interpret),
                            form=form)


@functools.partial(
    jax.jit, static_argnames=("tile", "dtype", "block_ctrl", "interpret", "form")
)
def _bsi_adjoint_jit(g, tile, *, dtype, block_ctrl, interpret,
                     form="separable"):
    out_dtype = jnp.dtype(dtype) if dtype is not None else jnp.float32
    dx, dy, dz = tile
    X, Y, Z, c = g.shape
    if X % dx or Y % dy or Z % dz:
        raise ValueError(f"cotangent shape {g.shape} not a multiple of {tile}")
    num_ctrl = (X // dx + 3, Y // dy + 3, Z // dz + 3)
    if block_ctrl is None:
        block_ctrl = pick_block_ctrl(num_ctrl, tile, c, g.dtype.itemsize)
    block_ctrl = tuple(min(b, n) for b, n in zip(block_ctrl, num_ctrl))
    # pad: 3 zero tiles per side (uniform windows) + control count up to a
    # block multiple (the extra rows are cropped from the output).
    pads = [(3 * d, (3 + (-n) % b) * d)
            for n, b, d in zip(num_ctrl, block_ctrl, tile)]
    gp = jnp.pad(g, pads + [(0, 0)])
    if form == "matmul":
        b = basis_matrix(tile, jnp.float32)
        kern = functools.partial(bsi_adjoint_matmul_pallas, b=b)
    elif form == "separable":
        luts = tuple(weight_lut(d, jnp.float32) for d in tile)
        kern = lambda slab, **kw: bsi_adjoint_separable_pallas(  # noqa: E731
            slab, *luts, **kw)
    else:
        raise ValueError(f"unknown adjoint form {form!r}")

    nz_pad = gp.shape[2] // dz - 3  # padded control count along z
    # budget read at trace time (not def time) so tests can patch it
    chunk = _pick_z_chunk(gp.shape, nz_pad, block_ctrl[2], gp.dtype.itemsize,
                          budget=_VMEM_BUDGET_BYTES)
    outs = []
    for k0 in range(0, nz_pad, chunk):
        k1 = min(k0 + chunk, nz_pad)
        slab = gp[:, :, k0 * dz : (k1 + 3) * dz]
        outs.append(kern(
            slab, tile=tile, block_ctrl=block_ctrl,
            out_dtype=out_dtype, interpret=interpret))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    return out[: num_ctrl[0], : num_ctrl[1], : num_ctrl[2]]


# --- fused level step (BSI + warp + similarity, kernels.bsi_fused) ---------

# Similarity kinds with a fused partial-sum accumulator.  The spec tuples
# come from ``repro.core.similarity.fused_spec`` (first element = kind).
FUSED_SIM_KINDS = ("ssd", "ncc", "lncc", "nmi")


def fused_supported(vol_shape, sim_spec, itemsize=4,
                    budget=_VMEM_BUDGET_BYTES):
    """Whether the fused kernel can run this level: ``(ok, reason)``.

    The fused kernel pins the moving *and* fixed volumes in VMEM (the warp
    is a VMEM gather), so it is bounded by volume size, not grid size —
    beyond the budget the unfused tiled kernels remain the path.  The
    similarity must also have a fused accumulator (a registered kind with
    known parameters; custom callables don't).
    """
    if sim_spec is None or sim_spec[0] not in FUSED_SIM_KINDS:
        return False, "similarity has no fused accumulator"
    vox = 1
    for s in vol_shape:
        vox *= int(s)
    if 3 * vox * itemsize > budget:
        return False, (f"volume {tuple(int(s) for s in vol_shape)} exceeds "
                       "the fused kernel's VMEM volume budget")
    return True, ""


def pick_block_tiles_fused(num_tiles, tile, extra, sim_spec, itemsize,
                           budget=_VMEM_BUDGET_BYTES):
    """Tile-block for the fused kernel: cube-ish, VMEM-bounded.

    Per-voxel temporaries dominate: the displacement block plus the eight
    gather/lerp operands (~24 lanes), and for NMI the two ``(voxels, bins)``
    Parzen weight blocks — the only place the histogram width ever
    materialises.
    """
    lanes = 24
    if sim_spec[0] == "nmi":
        lanes += 2 * int(sim_spec[1])

    def block_bytes(bt):
        vox = 1
        win = 1
        for b, e, d in zip(bt, extra, tile):
            vox *= (b + e) * d
            win *= b + e + 3
        return (vox * lanes + 24 * win) * itemsize

    return _shrink_to_budget(num_tiles, block_bytes, budget)


def fused_similarity_loss(phi, moving, fixed, tile, *, sim_spec,
                          compute_dtype=None, block_tiles=None,
                          interpret=None, disp_form="separable"):
    """Similarity loss of the warped moving volume — fused, no dense field.

    Computes ``sim(warp(moving, bsi(phi)), fixed)`` where ``sim`` is the
    registry loss named by ``sim_spec`` (see
    ``repro.core.similarity.fused_spec``) without ever materialising the
    ``(X, Y, Z, 3)`` displacement field or the warped volume in HBM: the
    Pallas kernel (``kernels.bsi_fused``) accumulates partial sums per
    VMEM tile-block and only the tiny reduction block reaches the host,
    where this dispatcher finishes the registry-exact scalar formula.
    Two-pass for NCC (mean of the warped volume) and NMI (its min/max).
    ``disp_form`` picks the displacement stage's BSI contraction
    (``separable`` sweeps or the ``matmul`` MXU form — see
    ``kernels.bsi_fused._disp_block``).

    Forward only — the differentiable wrapper is
    ``repro.core.ffd.fused_warp_loss``.
    """
    if interpret is None:
        interpret = default_interpret()
    cd = None if compute_dtype is None else jnp.dtype(compute_dtype).name
    return _fused_loss_jit(phi, moving, fixed, tuple(int(t) for t in tile),
                           sim_spec=tuple(sim_spec), compute_dtype=cd,
                           block_tiles=block_tiles, interpret=bool(interpret),
                           disp_form=disp_form)


@functools.partial(jax.jit, static_argnames=(
    "tile", "sim_spec", "compute_dtype", "block_tiles", "interpret",
    "disp_form"))
def _fused_loss_jit(phi, moving, fixed, tile, *, sim_spec, compute_dtype,
                    block_tiles, interpret, disp_form="separable"):
    kind = sim_spec[0]
    if kind not in FUSED_SIM_KINDS:
        raise ValueError(f"no fused kernel for similarity spec {sim_spec!r}")
    if fixed.shape != moving.shape:
        raise ValueError(f"shape mismatch: {fixed.shape} vs {moving.shape}")
    vol_shape = tuple(int(s) for s in moving.shape)
    X, Y, Z = vol_shape
    num_tiles = tuple(int(n) - 3 for n in phi.shape[:3])
    for n, d, s in zip(num_tiles, tile, vol_shape):
        if n * d < s:
            raise ValueError(f"control grid {phi.shape} does not cover "
                             f"volume {vol_shape} at tile spacing {tile}")
    if kind == "lncc":
        # clamp like similarity.uniform_filter, then size the halo in tiles
        size = max(1, min(int(sim_spec[1]), X, Y, Z))
        sim_spec = ("lncc", size, float(sim_spec[2]))
        extra = tuple(-(-(size - 1) // d) for d in tile)
    else:
        extra = (0, 0, 0)
    if compute_dtype is not None:
        phi = phi.astype(compute_dtype)
        moving = moving.astype(compute_dtype)
    fixed32 = fixed.astype(jnp.float32)
    if block_tiles is None:
        block_tiles = pick_block_tiles_fused(num_tiles, tile, extra, sim_spec,
                                             phi.dtype.itemsize)
    block_tiles = tuple(min(b, t) for b, t in zip(block_tiles, num_tiles))
    grid = tuple(-(-t // b) for t, b in zip(num_tiles, block_tiles))
    # pad the control grid to whole blocks + the LNCC halo, and both volumes
    # to the matching voxel extent (padding is masked out of every sum)
    ctrl = tuple(g * b + e + 3 for g, b, e in zip(grid, block_tiles, extra))
    pads = [(0, c - p) for c, p in zip(ctrl, phi.shape[:3])] + [(0, 0)]
    if any(p[1] for p in pads):
        phi = jnp.pad(phi, pads)
    vshape_p = tuple((g * b + e) * d
                     for g, b, e, d in zip(grid, block_tiles, extra, tile))
    vpads = [(0, vp - s) for vp, s in zip(vshape_p, vol_shape)]
    mov_p = jnp.pad(moving, vpads) if any(p[1] for p in vpads) else moving
    fix_p = jnp.pad(fixed32, vpads) if any(p[1] for p in vpads) else fixed32
    luts = tuple(weight_lut(d, phi.dtype) for d in tile)
    n = X * Y * Z
    zeros = jnp.zeros((1, SCALAR_LANES), jnp.float32)

    def run(sim, scalars):
        return bsi_fused_pallas(phi, mov_p, fix_p, *luts, scalars, tile=tile,
                                block_tiles=block_tiles, extra=extra,
                                vol_shape=vol_shape, sim=sim,
                                interpret=interpret, disp_form=disp_form)

    if kind == "ssd":
        acc = run(sim_spec, zeros)
        return acc[0, 0] / n
    if kind == "ncc":
        st = run(("stats",), zeros)
        scal = zeros.at[0, 0].set(st[0, 0] / n).at[0, 1].set(jnp.mean(fixed32))
        acc = run(sim_spec, scal)
        denom = jnp.maximum(jnp.sqrt(acc[0, 1] * acc[0, 2]), 1e-8)
        return 1.0 - acc[0, 0] / denom
    if kind == "lncc":
        _, size, _ = sim_spec
        acc = run(sim_spec, zeros)
        npos = (X - size + 1) * (Y - size + 1) * (Z - size + 1)
        return 1.0 - acc[0, 0] / npos
    # nmi: joint Parzen histogram -> entropies, exactly similarity.nmi
    _, bins, _, eps = sim_spec
    st = run(("stats",), zeros)
    scal = (zeros.at[0, 0].set(st[0, 1]).at[0, 1].set(st[0, 2])
            .at[0, 2].set(jnp.min(fixed32)).at[0, 3].set(jnp.max(fixed32)))
    pab = run(sim_spec, scal) / n
    pa = jnp.sum(pab, axis=1)
    pb = jnp.sum(pab, axis=0)
    ha = -jnp.sum(pa * jnp.log(pa + eps))
    hb = -jnp.sum(pb * jnp.log(pb + eps))
    hab = -jnp.sum(pab * jnp.log(pab + eps))
    return 2.0 - (ha + hb) / (hab + eps)


def _pick_z_chunk(gp_shape, nz_pad, bz, itemsize, budget=_VMEM_BUDGET_BYTES):
    """Largest ``bz``-multiple z-chunk whose cotangent slab fits the budget.

    Each chunk of ``K`` control points re-reads a ``(K+3)``-tile slab — the
    3-tile halo is the chunk-level instance of the forward's Eq. A.4 overlap.
    Chunks never go below one block; a single minimal block that still
    exceeds the budget runs anyway (interpret mode tolerates it; on real
    hardware that is the signal to shrink ``block_ctrl``).
    """
    plane = gp_shape[0] * gp_shape[1] * gp_shape[3] * itemsize
    dz = gp_shape[2] // (nz_pad + 3)
    chunk = nz_pad
    while chunk > bz and (chunk + 3) * dz * plane > budget // 2:
        chunk = max(bz, (chunk // 2 // bz) * bz)
    return chunk
