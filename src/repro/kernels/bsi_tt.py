"""Thread-per-Tile BSI Pallas kernel (paper §3.2, TPU adaptation).

Paper-faithful structure: 64 weighted FMA accumulation steps per voxel, with
control points read once per tile-block from fast on-chip memory.  On TPU the
"registers" level is the VPU's vector registers, reached by vectorising the
whole tile-block; the halo-overlap saving of paper Eq. (A.4) happens on the
VMEM window read.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

__all__ = ["bsi_tt_pallas"]


def _kernel(wx_ref, wy_ref, wz_ref, phi_ref, out_ref, *, tile, block_tiles):
    dx, dy, dz = tile
    bx, by, bz = block_tiles
    c = out_ref.shape[-1]
    win = common.phi_window(phi_ref, block_tiles)  # (bx+3, by+3, bz+3, C)
    wx = wx_ref[...]
    wy = wy_ref[...]
    wz = wz_ref[...]

    acc = jnp.zeros((bx, dx, by, dy, bz, dz, c), out_ref.dtype)
    # 64 static accumulation steps — the paper's weighted-sum form.
    for l in range(4):
        for m in range(4):
            for n in range(4):
                w = (
                    wx[:, l][:, None, None] * wy[:, m][None, :, None] * wz[:, n][None, None, :]
                ).reshape(1, dx, 1, dy, 1, dz, 1)
                sl = win[l : l + bx, m : m + by, n : n + bz]
                acc = acc + sl[:, None, :, None, :, None, :] * w
    out_ref[...] = acc.reshape(bx * dx, by * dy, bz * dz, c)


@functools.partial(jax.jit, static_argnames=("tile", "block_tiles", "interpret"))
def bsi_tt_pallas(phi, wx, wy, wz, *, tile, block_tiles, interpret=True):
    """``phi (Tx+3, Ty+3, Tz+3, C)`` -> dense field, TT weighted-sum form.

    ``Tx/Ty/Tz`` must be divisible by ``block_tiles`` (ops.py pads).
    """
    tx, ty, tz = (int(n) - 3 for n in phi.shape[:3])
    c = phi.shape[3]
    bx, by, bz = block_tiles
    assert tx % bx == 0 and ty % by == 0 and tz % bz == 0, (phi.shape, block_tiles)
    grid = (tx // bx, ty // by, tz // bz)
    out_shape = jax.ShapeDtypeStruct(
        (tx * tile[0], ty * tile[1], tz * tile[2], c), phi.dtype
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, block_tiles=block_tiles),
        grid=grid,
        in_specs=[
            common.lut_spec(wx.shape),
            common.lut_spec(wy.shape),
            common.lut_spec(wz.shape),
            common.full_grid_spec(phi.shape),
        ],
        out_specs=common.out_spec(block_tiles, tile, c),
        out_shape=out_shape,
        interpret=interpret,
    )(wx, wy, wz, phi)
