"""Pure-jnp oracles for B-spline interpolation.

``bsi_ref`` is the ground-truth the Pallas kernels are validated against:
a direct, 64-term evaluation of paper Eq. (1) over an aligned uniform grid.
``bsi_points_ref`` evaluates Eq. (1) at arbitrary (non-aligned) continuous
coordinates and is used by the FFD/registration layer and by property tests.
"""
from __future__ import annotations

import itertools

import jax.numpy as jnp

from repro.core.bspline import bspline_basis, weight_lut

__all__ = ["bsi_ref", "bsi_points_ref"]


def bsi_ref(phi, tile, dtype=None):
    """Direct weighted-sum BSI (paper Eq. 1) on an aligned grid.

    Args:
      phi: control grid ``(Tx+3, Ty+3, Tz+3, C)`` (stored with +1 offset, see
        ``repro.core.bspline``).
      tile: ``(dx, dy, dz)`` tile size in voxels (the control spacing).
      dtype: accumulation/output dtype; defaults to ``phi.dtype``.

    Returns:
      Dense field ``(Tx*dx, Ty*dy, Tz*dz, C)``.
    """
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    dx, dy, dz = (int(t) for t in tile)
    tx, ty, tz = (int(n) - 3 for n in phi.shape[:3])
    c = phi.shape[3]
    wx = weight_lut(dx, dtype)
    wy = weight_lut(dy, dtype)
    wz = weight_lut(dz, dtype)

    out = jnp.zeros((tx, dx, ty, dy, tz, dz, c), dtype)
    for l, m, n in itertools.product(range(4), range(4), range(4)):
        w = (
            wx[:, l][:, None, None] * wy[:, m][None, :, None] * wz[:, n][None, None, :]
        ).reshape(1, dx, 1, dy, 1, dz, 1)
        sl = phi[l : l + tx, m : m + ty, n : n + tz]  # (tx, ty, tz, C)
        out = out + sl[:, None, :, None, :, None, :] * w
    return out.reshape(tx * dx, ty * dy, tz * dz, c)


def bsi_points_ref(phi, pts, spacing, dtype=None):
    """Evaluate Eq. (1) at arbitrary continuous voxel coordinates.

    Args:
      phi: control grid ``(nx, ny, nz, C)`` stored with the +1 offset.
      pts: ``(..., 3)`` voxel-space coordinates.
      spacing: ``(dx, dy, dz)`` control-point spacing in voxels.

    Returns:
      ``(..., C)`` interpolated values.
    """
    dtype = dtype or phi.dtype
    phi = jnp.asarray(phi, dtype)
    pts = jnp.asarray(pts, dtype)
    sp = jnp.asarray(spacing, dtype)
    q = pts / sp
    t = jnp.floor(q)
    u = q - t
    # Stored grid carries the +1 offset: paper index i = t-1 -> stored t.
    base = t.astype(jnp.int32)
    wx = bspline_basis(u[..., 0], dtype)
    wy = bspline_basis(u[..., 1], dtype)
    wz = bspline_basis(u[..., 2], dtype)

    nx, ny, nz = phi.shape[:3]
    out = jnp.zeros(pts.shape[:-1] + (phi.shape[-1],), dtype)
    for l, m, n in itertools.product(range(4), range(4), range(4)):
        ix = jnp.clip(base[..., 0] + l, 0, nx - 1)
        iy = jnp.clip(base[..., 1] + m, 0, ny - 1)
        iz = jnp.clip(base[..., 2] + n, 0, nz - 1)
        w = wx[..., l] * wy[..., m] * wz[..., n]
        out = out + w[..., None] * phi[ix, iy, iz]
    return out
