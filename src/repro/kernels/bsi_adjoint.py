"""Gather-based BSI adjoint Pallas kernel — thread-per-control-point.

The adjoint mirror of the forward kernels' Thread-per-Tile scheme: where the
forward broadcasts a VMEM-resident control grid over blocks of voxels, the
backward reduces a VMEM-resident voxel cotangent over blocks of *control
points*.  XLA's transpose of the gather/tt/ttli forwards is a per-voxel
scatter-add into the control grid — the maximal-data-movement pattern the
paper's §3 design exists to avoid; this kernel replaces it with the
separable-transpose contraction (``core.interpolate.bsi_adjoint_separable``)
run per control-point block:

* the dense cotangent is zero-padded by 3 tiles per axis (``ops.py``), so
  every control point uniformly owns the padded-tile window ``[i, i+4)`` —
  the exact mirror of the forward's ``(bt+3)^3`` halo window and the same
  Eq. (A.4) overlap saving, now on the gradient;
* each Pallas grid cell reduces its ``((bc+3)*d)^3`` cotangent window to a
  ``bc^3`` block of control-point gradients with three per-axis
  ``dot_general`` sweeps (MXU-friendly) + 4-band overlap-adds, accumulated
  in fp32 on-chip;
* the control-grid gradient (the small array) is written exactly once.

Two forms share that window/padding scheme (``ops.bsi_adjoint_pallas``
dispatches via ``form=``):

``separable``  the three per-axis sweep contraction above
               (``grad_impl="pallas"``);
``matmul``     the transposed matrix form (``grad_impl="matmul"``): the
               window's per-tile ``d^3`` cotangents contract against the
               ``(d^3, 64)`` Kronecker basis in one MXU-shaped
               ``dot_general`` — ``c4[k, t] = sum_v B[v, k] * g[t, v]``,
               the exact transpose of ``bsi_matmul``'s forward product —
               followed by the same shifted overlap-adds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

__all__ = ["bsi_adjoint_separable_pallas", "bsi_adjoint_matmul_pallas"]


def _band_sum(c4, b):
    """Overlap-add the four shifted bands: out[j] = sum_l c4[l, j + 3 - l].

    ``c4``: ``(4, bc+3, R)`` per-band contractions over padded tiles;
    returns ``(bc, R)``.  Band ``l`` contributes tile ``j + 3 - l`` to
    control point ``j`` — the transpose of the forward's ``phi[t + l]`` read.
    """
    return sum(c4[l, 3 - l : 3 - l + b] for l in range(4))


def _kernel(wx_ref, wy_ref, wz_ref, g_ref, out_ref, *, tile, block_ctrl):
    dx, dy, dz = tile
    bx, by, bz = block_ctrl
    c = out_ref.shape[-1]
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    # This cell's cotangent window: padded tiles [i0, i0 + bc + 3) per axis.
    win = g_ref[
        pl.ds(i * bx * dx, (bx + 3) * dx),
        pl.ds(j * by * dy, (by + 3) * dy),
        pl.ds(k * bz * dz, (bz + 3) * dz),
        :,
    ].astype(jnp.float32)  # fp32 on-chip accumulation for bf16 cotangents
    wx = wx_ref[...].astype(jnp.float32)
    wy = wy_ref[...].astype(jnp.float32)
    wz = wz_ref[...].astype(jnp.float32)
    X, Y = (bx + 3) * dx, (by + 3) * dy

    # z sweep: contract the in-tile voxel axis against the LUT, then
    # overlap-add -> (X, Y, bz, C).  Reverse axis order (z, y, x) so the
    # intermediates shrink as early as possible.
    u = win.reshape(X * Y, bz + 3, dz, c)
    c4 = jax.lax.dot_general(
        wz, u, (((0,), (2,)), ((), ())), preferred_element_type=jnp.float32
    )  # (4, X*Y, bz+3, C)
    h = _band_sum(jnp.moveaxis(c4, 1, 3).reshape(4, bz + 3, c * X * Y), bz)
    h = h.reshape(bz, c, X, Y)
    # y sweep -> (X, by, bz, C) laid out as (by, bz*C*X)
    u = h.reshape(bz * c * X, by + 3, dy).transpose(1, 2, 0)
    c4 = jax.lax.dot_general(
        wy, u, (((0,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (4, by+3, bz*C*X)
    h = _band_sum(c4, by).reshape(by, bz, c, X)
    # x sweep -> (bx, by, bz, C)
    u = h.reshape(by * bz * c, bx + 3, dx).transpose(1, 2, 0)
    c4 = jax.lax.dot_general(
        wx, u, (((0,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (4, bx+3, by*bz*C)
    h = _band_sum(c4, bx).reshape(bx, by, bz, c)
    out_ref[...] = h.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tile", "block_ctrl", "out_dtype", "interpret")
)
def bsi_adjoint_separable_pallas(gp, wx, wy, wz, *, tile, block_ctrl,
                                 out_dtype=jnp.float32, interpret=True):
    """Padded dense cotangent -> control-grid cotangent, blocked.

    Args:
      gp: ``((Nx+3)*dx, (Ny+3)*dy, (Nz+3)*dz, C)`` cotangent zero-padded by
        3 tiles per axis (``ops.bsi_adjoint_pallas`` pads), where ``N*`` is
        the stored control count, padded up to a ``block_ctrl`` multiple.
      wx, wy, wz: ``(d, 4)`` aligned-grid weight LUTs.
      tile: ``(dx, dy, dz)`` spacing; ``block_ctrl``: control points per
        Pallas grid cell (must divide ``N*``).

    Returns:
      ``(Nx, Ny, Nz, C)`` control-grid cotangent in ``out_dtype``.
    """
    dx, dy, dz = tile
    c = gp.shape[3]
    nx, ny, nz = (s // d - 3 for s, d in zip(gp.shape[:3], tile))
    bx, by, bz = block_ctrl
    assert nx % bx == 0 and ny % by == 0 and nz % bz == 0, (gp.shape, block_ctrl)
    grid = (nx // bx, ny // by, nz // bz)
    out_shape = jax.ShapeDtypeStruct((nx, ny, nz, c), out_dtype)
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile, block_ctrl=block_ctrl),
        grid=grid,
        in_specs=[
            common.lut_spec(wx.shape),
            common.lut_spec(wy.shape),
            common.lut_spec(wz.shape),
            common.full_grid_spec(gp.shape),
        ],
        out_specs=pl.BlockSpec(
            (bx, by, bz, c), lambda i, j, k: (i, j, k, 0)
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(wx, wy, wz, gp)


def _kernel_matmul(b_ref, g_ref, out_ref, *, tile, block_ctrl):
    dx, dy, dz = tile
    bx, by, bz = block_ctrl
    c = out_ref.shape[-1]
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    win = g_ref[
        pl.ds(i * bx * dx, (bx + 3) * dx),
        pl.ds(j * by * dy, (by + 3) * dy),
        pl.ds(k * bz * dz, (bz + 3) * dz),
        :,
    ].astype(jnp.float32)  # fp32 on-chip accumulation for bf16 cotangents
    b = b_ref[...].astype(jnp.float32)  # (dx*dy*dz, 64)

    # per-tile layout: (tiles, d^3, C) — each padded tile's voxel cotangents
    # as one column block of the transposed product
    u = win.reshape(bx + 3, dx, by + 3, dy, bz + 3, dz, c)
    u = u.transpose(0, 2, 4, 1, 3, 5, 6).reshape(-1, dx * dy * dz, c)
    c4 = jax.lax.dot_general(
        b, u, (((0,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (64, tiles, C): c4[k, t] = sum_v B[v, k] * g[t, v]
    c4 = c4.reshape(4, 4, 4, bx + 3, by + 3, bz + 3, c)
    # shifted overlap-adds, one axis at a time: band (l, m, n) of tile t
    # lands on control point t + (l, m, n) - 3 (transpose of the forward's
    # phi[t + (l, m, n)] reads; same geometry as _band_sum)
    h = sum(c4[l, :, :, 3 - l : 3 - l + bx] for l in range(4))
    h = sum(h[m, :, :, 3 - m : 3 - m + by] for m in range(4))
    h = sum(h[n, :, :, 3 - n : 3 - n + bz] for n in range(4))
    out_ref[...] = h.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tile", "block_ctrl", "out_dtype", "interpret")
)
def bsi_adjoint_matmul_pallas(gp, b, *, tile, block_ctrl,
                              out_dtype=jnp.float32, interpret=True):
    """Transposed-matmul adjoint: same contract as the separable kernel.

    Identical padding/window scheme and output as
    :func:`bsi_adjoint_separable_pallas`, but the per-block reduction is one
    ``(64, d^3) @ (d^3, tiles*C)`` MXU contraction against the Kronecker
    basis ``b`` (``repro.core.bspline.basis_matrix``) instead of three
    per-axis sweeps.
    """
    dx, dy, dz = tile
    c = gp.shape[3]
    nx, ny, nz = (s // d - 3 for s, d in zip(gp.shape[:3], tile))
    bx, by, bz = block_ctrl
    assert nx % bx == 0 and ny % by == 0 and nz % bz == 0, (gp.shape, block_ctrl)
    grid = (nx // bx, ny // by, nz // bz)
    out_shape = jax.ShapeDtypeStruct((nx, ny, nz, c), out_dtype)
    return pl.pallas_call(
        functools.partial(_kernel_matmul, tile=tile, block_ctrl=block_ctrl),
        grid=grid,
        in_specs=[
            common.lut_spec(b.shape),
            common.full_grid_spec(gp.shape),
        ],
        out_specs=pl.BlockSpec(
            (bx, by, bz, c), lambda i, j, k: (i, j, k, 0)
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(b, gp)
