"""int8 error-feedback gradient compression for cross-pod all-reduce.

At multi-pod scale the ``pod`` axis crosses the slow inter-pod links (DCN
or optical), so gradient bytes there are the scaling bottleneck.  Classic
remedy (1-bit Adam / EF-SGD lineage): quantize the gradient before the
slow all-reduce, keep the quantization error in a local *error-feedback*
buffer, and add it back next step — unbiased in the long run, 4x fewer
bytes at int8.

Two entry points:

* ``make_compressor(...)`` — a gradient transform for the SPMD train step:
  quantize -> dequantize with EF state (the collective itself is emitted
  by GSPMD; the value crossing it is the coarse int8-reconstructed one).
* ``compressed_psum(...)`` — the explicit shard_map form: quantize, psum
  int32, dequantize — used where the collective must *actually* carry
  int8 (demonstrated + tested at small scale in tests/test_distributed.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

__all__ = ["make_compressor", "compressed_psum", "quantize_int8", "dequantize_int8"]


def quantize_int8(x, axis=None):
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def make_compressor():
    """Returns ``compress(grads, ef_state) -> (grads', ef_state')``.

    ``ef_state`` starts as None; pass the returned state back each step.
    """

    def compress(grads, ef):
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        ef_leaves = (treedef.flatten_up_to(ef) if ef is not None
                     else [jnp.zeros_like(l, jnp.float32) for l in leaves])
        out, new_ef = [], []
        for g, e in zip(leaves, ef_leaves):
            corrected = g.astype(jnp.float32) + e
            q, scale = quantize_int8(corrected)
            deq = dequantize_int8(q, scale)
            out.append(deq.astype(g.dtype))
            new_ef.append(corrected - deq)
        return (jax.tree_util.tree_unflatten(treedef, out),
                jax.tree_util.tree_unflatten(treedef, new_ef))

    return compress


def compressed_psum(x, axis_name):
    """Explicit int8-over-the-wire psum (use inside shard_map).

    int8 values are summed in int32 (no overflow for <=2^23 participants),
    scales are averaged; the reconstruction uses the mean scale.
    """
    q, scale = quantize_int8(x)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total.astype(jnp.float32) * (scale_sum / n)).astype(x.dtype)
