"""AdamW with global-norm clipping, cosine schedule and low-precision moments.

Pure-pytree implementation (no optax dependency).  ``moment_dtype=bfloat16``
halves optimizer-state HBM — the difference between arctic-480b fitting a
single pod or not (DESIGN.md §5); parameters stay in float32 master copies
and are cast to the compute dtype inside the loss.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "opt_init", "opt_update", "abstract_opt", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"


def opt_init(params, ocfg: OptConfig):
    dt = jnp.dtype(ocfg.moment_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(z, params),
        "v": jax.tree_util.tree_map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt(abstract_params, ocfg: OptConfig):
    dt = jnp.dtype(ocfg.moment_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(z, abstract_params),
        "v": jax.tree_util.tree_map(z, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lr_at(step, ocfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(ocfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - ocfg.warmup_steps)
        / jnp.maximum(ocfg.total_steps - ocfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return ocfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(tree))
    )


def opt_update(grads, opt, params, ocfg: OptConfig):
    """Returns (new_params, new_opt, stats)."""
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(step, ocfg)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(ocfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + (1 - b1) * g
        vf = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        u = (mf / bc1) / (jnp.sqrt(vf / bc2) + ocfg.eps)
        newp = p.astype(jnp.float32) - lr * (u + ocfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mf.astype(mdt), vf.astype(mdt)

    flat, treedef = jax.tree_util.tree_flatten(params)
    gflat = treedef.flatten_up_to(grads)
    mflat = treedef.flatten_up_to(opt["m"])
    vflat = treedef.flatten_up_to(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat, gflat, mflat, vflat)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
