"""Pipeline parallelism: GPipe-style microbatched stage execution.

An alternative use of the inter-pod axis (DESIGN.md §5): instead of DP,
the layer stack splits into ``n_stages`` contiguous stages; microbatches
stream through with ``jax.lax.ppermute`` hops between stage neighbours
inside ``shard_map``.  Fill+drain bubble = (n_stages-1)/(n_micro+n_stages-1);
the schedule is the classic GPipe one (all-forward, all-backward via jax
autodiff through the permutes).

Works on any 1-D mesh axis; exercised at smoke scale in
tests/test_distributed.py::test_pipeline_parallel_matches_serial.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x/0.5.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map

# pvary only exists (and is only needed) on jax versions whose shard_map
# tracks varying-axis state; older shard_map runs with check_rep=False.
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn, stage_params, x, *, mesh, axis="pp", n_micro=None):
    """Run ``x`` through ``n_stages`` pipeline stages.

    Args:
      stage_fn: ``(params_for_stage, h) -> h`` — one stage's computation.
      stage_params: pytree with leading axis ``n_stages`` (stage-sharded).
      x: global batch ``(B, ...)``; B must divide into microbatches.
      mesh: mesh containing ``axis`` of size n_stages.
      n_micro: number of microbatches (default: n_stages).

    Returns the pipeline output ``(B, ...)`` (resident on the last stage,
    replicated back through the collective at the end).
    """
    n_stages = mesh.shape[axis]
    n_micro = n_micro or n_stages
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    micro = x.reshape(n_micro, B // n_micro, *x.shape[1:])

    def run(params, micro):
        # params: this stage's slice (leading axis removed by shard_map)
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        n_steps = n_micro + n_stages - 1
        # mark carries as axis-varying (they depend on the stage index)
        buf = _pvary(jnp.zeros_like(micro[0]), (axis,))
        outs = _pvary(jnp.zeros_like(micro), (axis,))
        micro = _pvary(micro, (axis,))

        def step(i, carry):
            buf, outs = carry
            # stage 0 injects microbatch i (when in range)
            inject = jnp.where(i < n_micro, i, 0)
            buf = jnp.where(stage == 0,
                            jnp.where(i < n_micro, micro[inject], buf), buf)
            buf = stage_fn(params, buf)
            # emit from the last stage: microbatch index i - (n_stages - 1)
            out_ix = i - (n_stages - 1)
            valid = (out_ix >= 0) & (out_ix < n_micro)
            outs = jnp.where(
                (stage == n_stages - 1) & valid,
                outs.at[jnp.clip(out_ix, 0, n_micro - 1)].set(buf), outs)
            # shift activations to the next stage
            buf = jax.lax.ppermute(
                buf, axis, [(j, (j + 1) % n_stages) for j in range(n_stages)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_steps, step, (buf, outs))
        # bring the result (held by the last stage) to every stage
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    kwargs = dict(mesh=mesh, in_specs=(PS(axis), PS()), out_specs=PS())
    try:
        # older shard_map's replication checker rejects the stage-varying
        # carries that pvary would have annotated; disable it there.
        shard = _shard_map(run, check_rep=False, **kwargs)
    except TypeError:
        shard = _shard_map(run, **kwargs)
    out = shard(stage_params, micro)
    return out.reshape(B, *x.shape[1:])
