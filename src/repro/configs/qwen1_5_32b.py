"""qwen1.5-32b [dense] — [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="qwen1.5-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
        d_ff=27392, vocab_size=152064, head_dim=128,
        qkv_bias=True, rope_theta=1e6,
        source="[hf:Qwen/Qwen1.5-0.5B; hf]",
        notes="QKV bias; MHA (kv=40)",
    ),
    smoke=ModelConfig(
        name="qwen1.5-32b", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16, qkv_bias=True,
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
