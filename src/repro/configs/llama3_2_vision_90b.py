"""llama-3.2-vision-90b [vlm] — [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

"100L" realised as 20 groups of (4 self-attn + 1 gated cross-attn) layers =
80 + 20, matching Meta's description.  The vision tower is a STUB per the
assignment: ``input_specs`` supplies 1601 precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256, head_dim=128,
        cross_attn_every=5, img_tokens=1601, rope_theta=5e5,
        source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
        notes="cross-attn image layers every 5th; patch embeddings stubbed",
    ),
    smoke=ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        cross_attn_every=2, img_tokens=16,
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
