"""hymba-1.5b [hybrid] — [arXiv:2411.13676; hf]."""
from repro.configs.base import ModelConfig, register

# full attention at the first, middle and last layers (Hymba paper), SWA rest
_PATTERN = tuple(0 if i in (0, 15, 31) else 1 for i in range(32))

register(
    ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        d_ff=5504, vocab_size=32001, head_dim=64,
        window=1024, window_pattern=_PATTERN,
        ssm_state=16, mamba_expand=2, mamba_conv=4,
        seq_parallel=False,  # measured: mamba's chunked scan re-gathers a
                             # seq-sharded residual (EXPERIMENTS §Perf)
        source="[arXiv:2411.13676; hf]",
        notes="parallel attention + mamba heads per block; 3 full-attn layers",
    ),
    smoke=ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        window=8, window_pattern=(0, 1), ssm_state=4,
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
