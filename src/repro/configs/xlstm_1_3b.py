"""xlstm-1.3b [ssm] — [arXiv:2405.04517; unverified]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_every=8,
        remat_policy="dots",  # measured: recurrent/expert recompute under "nothing" costs more HBM traffic than dot saves (EXPERIMENTS §Perf)   # xLSTM[7:1]: 7 mLSTM blocks then 1 sLSTM per group
        source="[arXiv:2405.04517; unverified]",
        notes="mLSTM (chunked-parallel) + sLSTM (sequential scan); d_ff=0",
    ),
    smoke=ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=512, slstm_every=2,
        remat=False, loss_chunk=64,
    ),
)
