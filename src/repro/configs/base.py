"""Model/run configuration: the one dataclass all 10 assigned archs fit in.

Each ``src/repro/configs/<arch>.py`` instantiates ``ModelConfig`` with the
exact assigned numbers and registers it (plus a reduced ``smoke`` variant for
CPU tests).  ``input_specs`` builds the ShapeDtypeStruct stand-ins for every
(config x shape) dry-run cell — no device allocation.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeCfg", "SHAPES", "register", "get_config", "list_configs", "input_specs"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # --- attention pattern ---
    window: int = 0                  # sliding window size; 0 = full attention
    window_pattern: tuple = ()       # per-layer: 1 = local (use window), 0 = global; cycled
    attn_logit_softcap: float = 0.0  # gemma2-style tanh softcap (0 = off)
    final_logit_softcap: float = 0.0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    slstm_every: int = 0             # xLSTM: every k-th block is sLSTM
    mamba_conv: int = 4
    mamba_expand: int = 2
    # --- enc-dec / vlm frontends (stubs per assignment) ---
    encoder_layers: int = 0
    encoder_seq_divisor: int = 4     # stub frame rate: enc_len = seq // divisor
    cross_attn_every: int = 0        # every k-th decoder layer adds cross-attn
    img_tokens: int = 0
    # --- numerics / memory ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu (GLU) | gelu (plain MLP)
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots (see DESIGN.md §Perf)
    seq_parallel: bool = True        # SP residual stream (off for recurrent
                                     # families: chunk reshapes re-gather)
    attn_remat: bool = True      # inner checkpoint: recompute attention probs
    scan_layers: bool = True
    loss_chunk: int = 1024           # sequence-chunked xent to bound logits
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    kv_cache_dtype: str = "bfloat16" # bfloat16 | int8
    # --- provenance ---
    source: str = ""                 # [source; verified-tier] from assignment
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def layer_windows(self) -> tuple:
        """Per-layer window size: 0 = full attention, >0 = sliding window."""
        if not self.window_pattern:
            return (self.window,) * self.num_layers
        pat = self.window_pattern
        return tuple(
            self.window if pat[i % len(pat)] else 0 for i in range(self.num_layers)
        )

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch has a long-context (500k) decode path."""
        if self.family in ("ssm", "hybrid"):
            return True
        wins = self.layer_windows()
        # sliding-window-dominant attention counts (gemma local:global)
        return bool(wins) and sum(1 for w in wins if w > 0) >= len(wins) // 2


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict = {}
_ARCH_MODULES = [
    "qwen1_5_32b", "gemma3_1b", "gemma2_2b", "internlm2_1_8b", "qwen2_moe_a2_7b",
    "arctic_480b", "xlstm_1_3b", "hymba_1_5b", "whisper_base",
    "llama3_2_vision_90b", "bsi_paper",
]


def register(cfg: ModelConfig, smoke: ModelConfig | None = None):
    _REGISTRY[cfg.name] = (cfg, smoke)
    return cfg


def _load_all():
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg, smoke_cfg = _REGISTRY[name]
    if smoke:
        if smoke_cfg is None:
            raise KeyError(f"{name} has no smoke variant")
        return smoke_cfg
    return cfg


def list_configs() -> list:
    _load_all()
    return sorted(_REGISTRY)


def cell_supported(cfg: ModelConfig, shape: ShapeCfg) -> tuple:
    """(supported, reason) for an (arch x shape) dry-run cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: no sub-quadratic 500k path (DESIGN.md §6.9)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict:
    """ShapeDtypeStruct stand-ins for one dry-run cell (weak-type correct)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "encdec":
        enc_len = S // cfg.encoder_seq_divisor
        specs["frame_embeddings"] = jax.ShapeDtypeStruct(
            (B, enc_len, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm":
        specs["image_embeddings"] = jax.ShapeDtypeStruct(
            (B, cfg.img_tokens, cfg.d_model), jnp.bfloat16
        )
    return specs
