"""The paper's own workload: BSI dense-field expansion per dataset volume.

Not a ModelConfig — the BSI "arch" is the paper's kernel applied to the five
registration volumes of paper Table 2.  The dry-run/roofline treat it as an
extra architecture (``--arch bsi_paper``), lowering the dense-field expansion
for each volume at the paper's default 5^3 tile plus the sweep tiles.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BsiWorkload:
    name: str
    volume: tuple      # voxels (paper Table 2)
    tile: tuple = (5, 5, 5)
    channels: int = 3
    mode: str = "ttli"


BSI_WORKLOADS = [
    BsiWorkload("phantom1", (512, 228, 385)),
    BsiWorkload("phantom2", (294, 130, 208)),
    BsiWorkload("phantom3", (294, 130, 208)),
    BsiWorkload("porcine1", (303, 167, 212)),
    BsiWorkload("porcine2", (267, 169, 237)),
]
