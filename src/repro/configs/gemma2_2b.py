"""gemma2-2b [dense] — [arXiv:2408.00118; hf]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="gemma2-2b", family="dense",
        num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
        d_ff=9216, vocab_size=256000, head_dim=256,
        window=4096, window_pattern=(1, 0),   # alternating local/global
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        source="[arXiv:2408.00118; hf]",
        notes="local+global alternating; logit softcaps",
    ),
    smoke=ModelConfig(
        name="gemma2-2b", family="dense",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        window=8, window_pattern=(1, 0),
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
