"""qwen2-moe-a2.7b [moe] — [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=0, vocab_size=151936, head_dim=128,
        num_experts=60, top_k=4, num_shared_experts=4, moe_d_ff=1408,
        source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
        notes="4 shared + 60 routed top-4; per-expert d_ff=1408",
    ),
    smoke=ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=512, head_dim=16,
        num_experts=8, top_k=2, num_shared_experts=2, moe_d_ff=32,
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
