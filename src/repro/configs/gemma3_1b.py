"""gemma3-1b [dense] — [hf:google/gemma-3-1b-pt; unverified]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="gemma3-1b", family="dense",
        num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
        d_ff=6912, vocab_size=262144, head_dim=256,
        window=512, window_pattern=(1, 1, 1, 1, 1, 0),  # 5 local : 1 global
        rope_theta=1e6,
        source="[hf:google/gemma-3-1b-pt; unverified]",
        notes="5:1 local:global sliding window (512); 128k context",
    ),
    smoke=ModelConfig(
        name="gemma3-1b", family="dense",
        num_layers=6, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512, head_dim=16,
        window=8, window_pattern=(1, 1, 1, 1, 1, 0),
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
