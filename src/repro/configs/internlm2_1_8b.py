"""internlm2-1.8b [dense] — [arXiv:2403.17297; hf]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="internlm2-1.8b", family="dense",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
        d_ff=8192, vocab_size=92544, head_dim=128,
        source="[arXiv:2403.17297; hf]",
        notes="GQA kv=8",
    ),
    smoke=ModelConfig(
        name="internlm2-1.8b", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
