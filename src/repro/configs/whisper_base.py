"""whisper-base [audio encdec] — [arXiv:2212.04356; unverified].

"6L" realised as 6 encoder + 6 decoder layers (whisper-base actual).  The
conv frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings at seq/4 rate.
"""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="whisper-base", family="encdec",
        num_layers=12, encoder_layers=6,
        d_model=512, num_heads=8, num_kv_heads=8,
        d_ff=2048, vocab_size=51865, head_dim=64,
        norm="layernorm", act="gelu",
        source="[arXiv:2212.04356; unverified]",
        notes="enc-dec; conv frontend stubbed (frame embeddings input)",
    ),
    smoke=ModelConfig(
        name="whisper-base", family="encdec",
        num_layers=4, encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        norm="layernorm", act="gelu",
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
