"""arctic-480b [moe] — [hf:Snowflake/snowflake-arctic-base; hf]."""
from repro.configs.base import ModelConfig, register

register(
    ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000, head_dim=128,
        num_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
        source="[hf:Snowflake/snowflake-arctic-base; hf]",
        notes="128 experts top-2 in parallel with a dense residual FFN",
    ),
    smoke=ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512, head_dim=16,
        num_experts=8, top_k=2, moe_d_ff=96, dense_residual=True,
        remat=False, loss_chunk=64, attn_q_chunk=32, attn_kv_chunk=32,
    ),
)
