"""Serving benchmark: continuous batching vs sequential ``register_batch``.

A Poisson stream of mixed-difficulty registration requests is played twice
against the same compiled programs:

* **sequential** — the pre-``engine.serve`` serving idiom: whenever the
  device is free, take the oldest ``lanes`` queued pairs and run one
  ``register_batch`` (with the same early-stopping config).  The batch-wide
  while-loop runs until the *slowest* pair converges, so easy pairs' lanes
  burn BSI steps long after their own convergence masks froze them.
* **continuous** — ``engine.serve.RegistrationScheduler``: the same lane
  width, but lanes freed by the convergence mask are immediately respliced
  with queued pairs, so lane-steps track useful work.

Both arms see identical pairs and identical arrival times (the arrival rate
is calibrated to ~2x the sequential arm's easy-pair capacity, so both arms
run backlogged and the comparison is throughput-dominated).  Reported rows:
p50/p99 request latency and time-per-pair (derived: pairs/sec) for each
arm.  The run *asserts* the acceptance criteria — continuous throughput
>= ``min_speedup`` x sequential at <= ``max_loss_excess`` relative
final-loss excess — so a scheduler regression fails the suite outright,
and the latency rows additionally ride the ``compare.py`` trajectory gate.
"""
from __future__ import annotations

import time

import numpy as np


def _pairs(shape, n, hard_every, seed):
    """Mixed-difficulty volume pairs: every ``hard_every``-th is hard.

    Easy pairs are a sub-voxel smooth shift of the fixed volume — Adam
    plateaus within a few steps.  Hard pairs add a large smooth deformation
    plus fresh texture, so the loss keeps improving for the whole budget.
    """
    rng = np.random.default_rng(seed)
    base = rng.normal(size=shape).astype(np.float32)
    x, y, z = np.meshgrid(*[np.linspace(0, np.pi, s) for s in shape],
                          indexing="ij")
    wave = np.sin(x) * np.sin(y) * np.sin(z)
    out = []
    for i in range(n):
        f = base + 0.05 * rng.normal(size=shape).astype(np.float32)
        if hard_every and i % hard_every == 0:
            m = np.roll(f, 3, axis=0) + 2.5 * wave.astype(np.float32)
            m = m + 0.3 * rng.normal(size=shape).astype(np.float32)
        else:
            m = f + 0.02 * wave.astype(np.float32)
        out.append((f.astype(np.float32), m.astype(np.float32)))
    return out


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def main(shape=(28, 24, 20), lanes=4, chunk=3, n=36, hard_every=4,
         iters=32, seed=0, reps=2, min_speedup=1.5, max_loss_excess=0.02):
    from repro.core.options import RegistrationOptions
    from repro.engine.batch import register_batch
    from repro.engine.convergence import ConvergenceConfig
    from repro.engine.serve import RegistrationScheduler

    opts = RegistrationOptions(
        tile=(6, 6, 6), levels=2, iters=iters, lr=0.1,
        mode="separable", impl="jnp", grad_impl="xla",
        stop=ConvergenceConfig(tol=2e-3, patience=3))
    pairs = _pairs(shape, n, hard_every, seed)
    easy = [p for i, p in enumerate(pairs)
            if not (hard_every and i % hard_every == 0)][:lanes]
    easy = (easy * lanes)[:lanes]

    # -- warm-up: compile both arms' programs outside the timed region ----
    F = np.stack([f for f, _ in easy])
    M = np.stack([m for _, m in easy])
    register_batch(F, M, options=opts)
    t0 = time.perf_counter()
    register_batch(F, M, options=opts)
    batch_s = time.perf_counter() - t0  # warm easy-batch time (calibration)
    warm = RegistrationScheduler(opts, lanes=lanes, chunk=chunk,
                                 max_queue=max(n, lanes))
    for f, m in easy:
        warm.submit(f, m)
    warm.run_until_idle()

    # Poisson arrivals at ~2x the sequential arm's easy-pair service rate:
    # both arms run backlogged, so throughput (not idle waiting) decides.
    rng = np.random.default_rng(seed + 1)
    mean_ia = batch_s / lanes / 2.0
    arrivals = np.concatenate(
        [[0.0], rng.exponential(mean_ia, n - 1)]).cumsum()

    def play_sequential():
        lat, finals, queue, done = {}, {}, [], 0
        start = time.perf_counter()
        while done < n:
            now = time.perf_counter() - start
            queue += [i for i in range(n)
                      if arrivals[i] <= now
                      and i not in lat and i not in queue]
            if not queue:
                nxt = min(arrivals[i] for i in range(n) if i not in lat)
                time.sleep(max(nxt - now, 0.0) + 1e-4)
                continue
            take, queue = queue[:lanes], queue[lanes:]
            # pad short batches up to the lane width by repeating the first
            # pair: register_batch compiles per batch shape, so variable B
            # would re-trace (and charge a compile) inside the timed region
            pad = take + take[:1] * (lanes - len(take))
            res = register_batch(
                np.stack([pairs[i][0] for i in pad]),
                np.stack([pairs[i][1] for i in pad]), options=opts)
            end = time.perf_counter() - start
            for j, i in enumerate(take):
                lat[i] = end - arrivals[i]
                finals[i] = float(res.losses[j, -1])
                done += 1
        return lat, finals, time.perf_counter() - start

    def play_continuous():
        sched = RegistrationScheduler(opts, lanes=lanes, chunk=chunk,
                                      max_queue=max(n, lanes))
        lat, finals, handles = {}, {}, {}
        start = time.perf_counter()
        submitted = 0
        while len(lat) < n:
            now = time.perf_counter() - start
            while submitted < n and arrivals[submitted] <= now:
                f, m = pairs[submitted]
                handles[submitted] = sched.submit(f, m)
                submitted += 1
            if sched.pending:
                sched.step()
            elif submitted < n:
                time.sleep(max(arrivals[submitted] - now, 0.0) + 1e-4)
            end = time.perf_counter() - start
            for i, h in handles.items():
                if h.done and i not in lat:
                    lat[i] = end - arrivals[i]
                    finals[i] = h.result().losses[-1]
        return lat, finals, time.perf_counter() - start, sched.stats

    # best-of-reps per arm (the usual min-timing discipline): one noisy
    # pass — a background process, a lazy first-touch — must not decide
    # the asserted speedup in either direction
    seq_lat, seq_fin, seq_make = min(
        (play_sequential() for _ in range(reps)), key=lambda r: r[-1])
    con_lat, con_fin, con_make, stats = min(
        (play_continuous() for _ in range(reps)), key=lambda r: r[2])

    seq_pps = n / seq_make
    con_pps = n / con_make
    speedup = con_pps / seq_pps
    excess = max(
        (con_fin[i] - seq_fin[i]) / max(abs(seq_fin[i]), 1e-12)
        for i in range(n))

    rows = [
        ("sequential_p50", _pctl(list(seq_lat.values()), 50) * 1e6,
         f"{seq_pps:.2f} pairs/s"),
        ("sequential_p99", _pctl(list(seq_lat.values()), 99) * 1e6,
         f"makespan {seq_make:.2f}s"),
        ("continuous_p50", _pctl(list(con_lat.values()), 50) * 1e6,
         f"{con_pps:.2f} pairs/s"),
        ("continuous_p99", _pctl(list(con_lat.values()), 99) * 1e6,
         f"makespan {con_make:.2f}s"),
        ("sequential_per_pair", 1e6 / seq_pps, f"{seq_pps:.2f} pairs/s"),
        ("continuous_per_pair", 1e6 / con_pps,
         f"{con_pps:.2f} pairs/s, x{speedup:.2f} vs sequential, "
         f"loss excess {excess * 100:.2f}%, {stats.recycled} recycled, "
         f"{stats.chunks} chunks"),
    ]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if speedup < min_speedup:
        raise AssertionError(
            f"continuous batching sustained only x{speedup:.2f} the "
            f"sequential throughput (acceptance floor x{min_speedup})")
    if excess > max_loss_excess:
        raise AssertionError(
            f"continuous final losses exceed sequential by "
            f"{excess * 100:.1f}% (allowed {max_loss_excess * 100:.0f}%)")
    return rows


if __name__ == "__main__":
    main()
