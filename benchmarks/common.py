"""Shared benchmark plumbing: timing, CSV output, volume scaling."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# Scaled-down stand-ins for the paper Table 2 volumes (CPU wall-time budget);
# pass --full to benchmark the exact paper resolutions.
SCALED_VOLUMES = {
    "phantom1": (128, 57, 96),
    "phantom2": (74, 33, 52),
    "phantom3": (74, 33, 52),
    "porcine1": (76, 42, 53),
    "porcine2": (67, 42, 59),
}
FULL_VOLUMES = {
    "phantom1": (512, 228, 385),
    "phantom2": (294, 130, 208),
    "phantom3": (294, 130, 208),
    "porcine1": (303, 167, 212),
    "porcine2": (267, 169, 237),
}
# CI smoke preset: just big enough that every code path executes.
TINY_VOLUMES = {
    "phantom2": (30, 26, 21),
    "porcine1": (31, 24, 27),
}


def peak_hbm_bytes(device=None):
    """Peak device-memory use in bytes, or None where unreported (CPU).

    Accelerator backends expose allocator counters via
    ``Device.memory_stats()``; the fused-level-step benchmark rows use this
    to show the dense field + warped volume never landing in HBM.  XLA:CPU
    returns no stats — callers print "n/a" rather than fabricating a number.
    """
    dev = device if device is not None else jax.local_devices()[0]
    stats_fn = getattr(dev, "memory_stats", None)
    stats = stats_fn() if callable(stats_fn) else None
    if not stats:
        return None
    return stats.get("peak_bytes_in_use")


def time_fn(fn, *args, reps=5, warmup=2):
    """Median wall time of a jitted fn (blocks on completion)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def grid_for(volume, tile, channels=3, seed=0):
    from repro.core import ffd

    gshape = ffd.grid_shape_for_volume(volume, tile)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(gshape + (channels,)), jnp.float32)


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
