"""Paper Figs. 5-7: BSI time-per-voxel and speedup vs tile size.

Wall-time on this container is CPU (the jnp forms are the paper's CPU-analog
measurements, Fig. 7); the TPU-kernel story is carried by the roofline
dry-run (`repro.launch.dryrun_bsi`).  ``gather`` plays NiftyReg-TV (the
paper's baseline), ``tt``/``ttli`` are the paper's contributions, and
``separable`` is this repo's beyond-paper form.

``--grad`` instead times the registration loop's real workload — forward +
backward through an SSD objective on the dense field — per
``(mode, impl, grad_impl)``: ``xla`` is plain autodiff of that forward
(whose transpose of the gather form is a per-voxel scatter-add), the other
adjoints are the analytic gather-only custom VJP (``jnp`` separable-
transpose / ``pallas`` kernel).  The derived column reports the backward-
path speedup over the same forward under ``xla`` autodiff.

``--fused`` times the full level step per similarity: the fused Pallas
megakernel (``core.ffd.fused_warp_loss`` — BSI + warp + similarity in one
VMEM pass, no dense field or warped volume in HBM) against the unfused
dense-field → warp → similarity composition, forward+backward.  On CPU
hosts the fused kernel runs in interpret mode, so these rows are a
correctness-path trajectory, not the TPU speedup story; the derived column
also reports peak device memory where the backend exposes it.

CSV: name,us_per_call,derived  where derived = ns/voxel | speedup-vs-gather
(forward sweep), speedup-vs-xla-autodiff (``--grad``), or
speedup-vs-unfused (``--fused``).
"""
from __future__ import annotations

import functools
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
if str(_ROOT) not in sys.path:  # direct execution: python benchmarks/...py
    sys.path.insert(0, str(_ROOT))
try:
    import repro  # noqa: F401  (installed via `pip install -e .`)
except ModuleNotFoundError:  # src-layout checkout without install
    sys.path.insert(0, str(_ROOT / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL_VOLUMES, SCALED_VOLUMES, emit, grid_for, time_fn
from repro.core import ffd

TILES = [3, 4, 5, 6, 7]
MODES = ["gather", "tt", "ttli", "separable", "matmul"]
# pallas/matmul adjoint kernels: interpret-only on CPU hosts
GRAD_IMPLS = ["xla", "jnp"]


def run(full=False, volumes=("phantom2", "porcine1"), reps=3, tiles=None,
        vol_table=None):
    vols = vol_table or (FULL_VOLUMES if full else SCALED_VOLUMES)
    rows = []
    for t in (tiles or TILES):
        tile = (t, t, t)
        base_ns = None
        for mode in MODES:
            total_t, total_vox = 0.0, 0
            for name in volumes:
                vol = vols[name]
                phi = grid_for(vol, tile)
                fn = jax.jit(functools.partial(
                    ffd.dense_field, tile=tile, vol_shape=vol, mode=mode))
                total_t += time_fn(fn, phi, reps=reps)
                total_vox += vol[0] * vol[1] * vol[2]
            ns_per_voxel = total_t / total_vox * 1e9
            if mode == "gather":
                base_ns = ns_per_voxel
            rows.append((
                f"bsi_speed/tile{t}/{mode}",
                round(total_t / len(volumes) * 1e6, 1),
                f"{ns_per_voxel:.2f}ns/vox|x{base_ns / ns_per_voxel:.2f}",
            ))
    return rows


def run_grad(full=False, volumes=("phantom2", "porcine1"), reps=3, tiles=None,
             vol_table=None, modes=None, impls=("jnp",), grad_impls=None):
    """Forward+backward rows per ``(mode, impl, grad_impl)`` (Adam-step load).

    Times ``jit(grad(loss))`` where ``loss`` is SSD of the dense field
    against a target — the BSI share of one optimisation step.  Each
    ``(mode, impl)``'s ``xla`` row (when present) is the baseline its
    custom-VJP rows are scored against.  ``impls`` defaults to the jnp
    forwards (Pallas forwards run interpret-mode on CPU hosts; pass
    ``impls=("jnp", "pallas")`` on TPU); combinations that cannot
    differentiate — a Pallas forward under ``xla`` autodiff — are skipped.
    Row names keep the historical ``{mode}-{grad_impl}`` form for the
    default jnp forward so baseline_ci.json keys stay stable.
    """
    vols = vol_table or (FULL_VOLUMES if full else SCALED_VOLUMES)
    rows = []
    for t in (tiles or TILES):
        tile = (t, t, t)
        for mode in (modes or MODES):
            for impl in impls:
                base_t = None
                for gi in (grad_impls or GRAD_IMPLS):
                    if impl == "pallas" and gi == "xla":
                        # the one known-undifferentiable combination (Pallas
                        # forwards have no VJP under plain autodiff); any
                        # other failure is a real regression and must crash
                        # the suite so the CI gate sees it
                        continue
                    total_t = 0.0
                    for name in volumes:
                        vol = vols[name]
                        phi = grid_for(vol, tile)
                        rng = np.random.default_rng(1)
                        tgt = jnp.asarray(rng.standard_normal(vol + (3,)),
                                          jnp.float32)

                        def loss(p, tile=tile, vol=vol, mode=mode, impl=impl,
                                 gi=gi, tgt=tgt):
                            d = ffd.dense_field(p, tile, vol, mode=mode,
                                                impl=impl, grad_impl=gi)
                            return jnp.sum((d - tgt) ** 2)

                        total_t += time_fn(jax.jit(jax.grad(loss)), phi,
                                           reps=reps)
                    if gi == "xla":
                        base_t = total_t
                    label = mode if impl == "jnp" else f"{mode}/{impl}"
                    rows.append((
                        f"bsi_grad/tile{t}/{label}-{gi}",
                        round(total_t / len(volumes) * 1e6, 1),
                        (f"x{base_t / total_t:.2f}-vs-xla" if base_t
                         else "no-xla-baseline"),
                    ))
    return rows


def run_fused(full=False, volumes=("phantom2",), reps=3, tiles=(5,),
              vol_table=None, similarities=("ssd", "ncc", "lncc", "nmi")):
    """Fused vs unfused level-step rows, forward+backward per similarity.

    Each pair of rows times ``jit(grad(...))`` of the same objective — the
    unfused dense-field → warp → similarity composition and the fused
    single-pass kernel — on the same volume and grid, so the ``_fused``
    row's derived column is a direct speedup over its ``_unfused`` sibling.
    A third ``_fused_matmul`` row runs the megakernel with its displacement
    stage in the MXU matrix form (``mode="matmul"`` → ``disp_form``), scored
    against the same unfused baseline.
    """
    from benchmarks.common import peak_hbm_bytes
    from repro.core.similarity import resolve_similarity

    vols = vol_table or (FULL_VOLUMES if full else SCALED_VOLUMES)
    rows = []
    for t in tiles:
        tile = (t, t, t)
        for sim in similarities:
            _, sim_fn = resolve_similarity(sim)
            total_un, total_fu, total_mm = 0.0, 0.0, 0.0
            for name in volumes:
                vol = vols[name]
                phi = grid_for(vol, tile)
                rng = np.random.default_rng(1)
                mov = jnp.asarray(rng.random(vol), jnp.float32)
                fix = jnp.asarray(rng.random(vol), jnp.float32)

                def unfused(p, tile=tile, vol=vol, sim_fn=sim_fn,
                            mov=mov, fix=fix):
                    d = ffd.dense_field(p, tile, vol)
                    return sim_fn(ffd.warp_volume(mov, d), fix)

                def fused(p, tile=tile, sim=sim, mov=mov, fix=fix):
                    return ffd.fused_warp_loss(p, mov, fix, tile,
                                               similarity=sim)

                def fused_mm(p, tile=tile, sim=sim, mov=mov, fix=fix):
                    return ffd.fused_warp_loss(p, mov, fix, tile,
                                               similarity=sim, mode="matmul")

                total_un += time_fn(jax.jit(jax.grad(unfused)), phi, reps=reps)
                total_fu += time_fn(jax.jit(jax.grad(fused)), phi, reps=reps)
                total_mm += time_fn(jax.jit(jax.grad(fused_mm)), phi,
                                    reps=reps)
            hbm = peak_hbm_bytes()
            hbm_s = "n/a" if hbm is None else f"{hbm / 2**20:.1f}MiB"
            n = len(volumes)
            rows.append((f"bsi_fused/tile{t}/{sim}_unfused",
                         round(total_un / n * 1e6, 1), "baseline"))
            rows.append((f"bsi_fused/tile{t}/{sim}_fused",
                         round(total_fu / n * 1e6, 1),
                         f"x{total_un / total_fu:.2f}-vs-unfused"
                         f"|peak_hbm={hbm_s}"))
            rows.append((f"bsi_fused/tile{t}/{sim}_fused_matmul",
                         round(total_mm / n * 1e6, 1),
                         f"x{total_un / total_mm:.2f}-vs-unfused"))
    return rows


def main(full=False, grad=False, fused=False, **kwargs):
    if fused:
        rows = run_fused(full, **kwargs)
    elif grad:
        rows = run_grad(full, **kwargs)
    else:
        rows = run(full, **kwargs)
    return emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    main(full="--full" in sys.argv, grad="--grad" in sys.argv,
         fused="--fused" in sys.argv)
