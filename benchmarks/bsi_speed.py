"""Paper Figs. 5-7: BSI time-per-voxel and speedup vs tile size.

Wall-time on this container is CPU (the jnp forms are the paper's CPU-analog
measurements, Fig. 7); the TPU-kernel story is carried by the roofline
dry-run (`repro.launch.dryrun_bsi`).  ``gather`` plays NiftyReg-TV (the
paper's baseline), ``tt``/``ttli`` are the paper's contributions, and
``separable`` is this repo's beyond-paper form.

CSV: name,us_per_call,derived  where derived = ns/voxel | speedup-vs-gather.
"""
from __future__ import annotations

import functools

import jax

from benchmarks.common import FULL_VOLUMES, SCALED_VOLUMES, emit, grid_for, time_fn
from repro.core import ffd

TILES = [3, 4, 5, 6, 7]
MODES = ["gather", "tt", "ttli", "separable"]


def run(full=False, volumes=("phantom2", "porcine1"), reps=3, tiles=None,
        vol_table=None):
    vols = vol_table or (FULL_VOLUMES if full else SCALED_VOLUMES)
    rows = []
    for t in (tiles or TILES):
        tile = (t, t, t)
        base_ns = None
        for mode in MODES:
            total_t, total_vox = 0.0, 0
            for name in volumes:
                vol = vols[name]
                phi = grid_for(vol, tile)
                fn = jax.jit(functools.partial(
                    ffd.dense_field, tile=tile, vol_shape=vol, mode=mode))
                total_t += time_fn(fn, phi, reps=reps)
                total_vox += vol[0] * vol[1] * vol[2]
            ns_per_voxel = total_t / total_vox * 1e9
            if mode == "gather":
                base_ns = ns_per_voxel
            rows.append((
                f"bsi_speed/tile{t}/{mode}",
                round(total_t / len(volumes) * 1e6, 1),
                f"{ns_per_voxel:.2f}ns/vox|x{base_ns / ns_per_voxel:.2f}",
            ))
    return rows


def main(full=False, **kwargs):
    return emit(run(full, **kwargs), ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
