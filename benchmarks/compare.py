"""Benchmark regression gate: fail CI when a ci-preset metric regresses.

Compares a fresh ``BENCH_ci.json`` (``benchmarks/run.py --preset ci
--json``) against the committed ``benchmarks/baseline_ci.json``.

CI runners differ in absolute speed (and from the machine that wrote the
baseline), so the gate has two tiers:

* **Per-row** (precise): each gated row's ``new/baseline`` ratio is
  divided by the median gated ratio — the machine-speed factor — and the
  row fails when the normalised ratio exceeds ``1 + threshold`` (default
  0.30).  Machine-invariant; catches a regression in any minority of rows
  but is blind to a slowdown hitting every gated row equally.
* **Suite-wide** (coarse): the sub-``--min-us`` timed rows (default floor
  5 ms; micro-timings are too noisy to gate individually) serve as
  calibration — if the gated median exceeds the calibration median by more
  than ``--suite-threshold`` (default 2.0x), the whole gated suite slowed
  in a way runner speed can't explain, and the gate fails.  The margin is
  deliberately generous: micro-rows (dispatch-bound) and multi-second rows
  (compute-bound) scale differently across runner classes, so a tight
  bound here would flake.

Rows present on only one side (new benchmarks seed the baseline at the
next refresh) are reported but never fail the gate.  A gated row whose
fresh measurement comes back zero/negative is a broken benchmark and
fails.

    python benchmarks/compare.py BENCH_ci.json
    python benchmarks/compare.py BENCH_ci.json --threshold 0.5
    python benchmarks/compare.py BENCH_ci.json --write-baseline  # refresh
"""
from __future__ import annotations

import argparse
import json
import shutil
import statistics
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline_ci.json"


def flatten(payload) -> dict:
    """{"suite/row-name": us_per_call} for every row in a BENCH json."""
    out = {}
    for suite, rows in payload.get("suites", {}).items():
        for row in rows:
            try:
                out[f"{suite}/{row['name']}"] = float(row["us_per_call"])
            except (KeyError, TypeError, ValueError):
                continue
    return out


def compare(new: dict, base: dict, *, threshold=0.30, min_us=5000.0,
            suite_threshold=2.0):
    """Returns ``(regressions, report_lines)``.

    A row regresses when new/base exceeds the median new/base ratio (the
    machine-speed factor) by more than ``threshold``, or when a gated row's
    fresh measurement comes back zero/negative (a broken benchmark must not
    read as an infinite speedup).  Additionally the gated *median* itself is
    checked against the calibration rows (see module docstring) so a
    slowdown hitting every gated row at once cannot normalise itself away.
    """
    gated = sorted(k for k in new if k in base and base[k] >= min_us)
    shared = [k for k in gated if new[k] > 0]
    # calibration rows: timed on both sides but below the gate floor —
    # individually noisy, but their median anchors the suite-wide check
    # because they are outside the gated suite.
    calib = [k for k in new if k in base
             and 0 < base[k] < min_us and new[k] > 0]
    report = []
    regressions = []
    for k in sorted(set(new) ^ set(base)):
        side = "new" if k in new else "baseline-only"
        report.append(f"  (unmatched, skipped) [{side}] {k}")
    for k in sorted(calib):
        report.append(f"  (below --min-us, calibration only) {k}")
    for k in gated:
        if new[k] <= 0:
            report.append(f"  [REGRESSION] {k}: baseline {base[k]:.0f}us but "
                          f"new run measured {new[k]:.0f}us — broken row")
            regressions.append((k, 0.0))
    if not shared:
        report.append("no comparable rows — gate passes vacuously"
                      if not regressions else "no comparable rows")
        return regressions, report

    ratios = {k: new[k] / base[k] for k in shared}
    machine = statistics.median(ratios.values())
    report.append(f"machine-speed factor (median gated ratio): x{machine:.3f}"
                  f" ({len(shared)} gated, {len(calib)} calibration rows)")
    if len(calib) >= 3:
        calib_med = statistics.median(new[k] / base[k] for k in calib)
        suite = machine / calib_med if calib_med > 0 else 1.0
        report.append(f"suite-wide check: gated median x{machine:.2f} vs "
                      f"calibration median x{calib_med:.2f} "
                      f"(ratio x{suite:.2f}, limit x{suite_threshold:.1f})")
        if suite > suite_threshold:
            report.append(
                "  [REGRESSION] the entire gated suite slowed more than "
                f"{suite_threshold:.1f}x beyond what the calibration rows "
                "attribute to runner speed")
            regressions.append(("<suite-wide>", suite))
    for k in shared:
        norm = ratios[k] / machine
        flag = "REGRESSION" if norm > 1.0 + threshold else "ok"
        report.append(f"  [{flag:10s}] {k}: {base[k]:.0f}us -> {new[k]:.0f}us"
                      f" (normalised x{norm:.2f})")
        if norm > 1.0 + threshold:
            regressions.append((k, norm))
    return regressions, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="fresh BENCH json (benchmarks/run.py --json)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="allowed normalised slowdown (0.30 = +30%%)")
    ap.add_argument("--min-us", type=float, default=5000.0,
                    help="gate rows at/above this baseline time; faster "
                         "rows calibrate runner speed instead")
    ap.add_argument("--suite-threshold", type=float, default=2.0,
                    help="fail when the gated median exceeds the "
                         "calibration median by this factor")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy NEW over the baseline instead of comparing")
    args = ap.parse_args(argv)

    if args.write_baseline:
        payload = json.loads(Path(args.new).read_text())
        if payload.get("failures"):
            print(f"refusing to refresh the baseline from a run with failed "
                  f"suites: {payload['failures']}")
            return 2
        shutil.copyfile(args.new, args.baseline)
        print(f"baseline refreshed: {args.new} -> {args.baseline}")
        return 0

    new = json.loads(Path(args.new).read_text())
    base = json.loads(Path(args.baseline).read_text())
    if new.get("failures"):
        print(f"new run has failed suites: {new['failures']}")
        return 2
    regressions, report = compare(flatten(new), flatten(base),
                                  threshold=args.threshold,
                                  min_us=args.min_us,
                                  suite_threshold=args.suite_threshold)
    print("\n".join(report))
    if regressions:
        print(f"\n{len(regressions)} metric(s) regressed more than "
              f"{args.threshold:.0%} beyond the machine factor:")
        for k, norm in regressions:
            print(f"  {k}: x{norm:.2f}")
        return 1
    print("\nbench gate: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
