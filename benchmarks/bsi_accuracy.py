"""Paper Tables 3-4: interpolation accuracy vs a float64 reference.

The paper compares each implementation against a double-precision CPU
reference; lerp-form implementations (TTLI / VT / VV) come out ~2x more
accurate thanks to FMA.  Here: float32 forms vs the float64 oracle
(x64 enabled locally for the reference only).

CSV: name,us_per_call,derived  where derived = mean|err| (1e-6 units).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.interpolate import MODES as JNP_MODES
from repro.kernels import ops

TILES = [3, 5, 7]


def _f64_reference(phi64, tile):
    # float64 oracle evaluated with the direct Eq. (1) weighted sum
    from repro.kernels.ref import bsi_ref

    return bsi_ref(phi64, tile)


def run(grid_pts=9, channels=3, tiles=None):
    import jax.numpy as jnp

    rows = []
    rng = np.random.default_rng(0)
    with jax.experimental.enable_x64():
        for t in (tiles or TILES):
            tile = (t, t, t)
            phi_np = rng.standard_normal((grid_pts,) * 3 + (channels,))
            ref = np.asarray(_f64_reference(jnp.asarray(phi_np, jnp.float64), tile))
            phi32 = jnp.asarray(phi_np, jnp.float32)
            for mode, fn in JNP_MODES.items():
                out = np.asarray(fn(phi32, tile), np.float64)
                err = np.mean(np.abs(out - ref)) * 1e6
                rows.append((f"bsi_accuracy/tile{t}/jnp_{mode}", 0.0,
                             f"{err:.3f}e-6"))
            for mode in ("tt", "ttli", "separable", "matmul"):
                out = np.asarray(
                    ops.bsi_pallas(phi32, tile, mode=mode), np.float64)
                err = np.mean(np.abs(out - ref)) * 1e6
                rows.append((f"bsi_accuracy/tile{t}/pallas_{mode}", 0.0,
                             f"{err:.3f}e-6"))
    return rows


def main(**kwargs):
    return emit(run(**kwargs), ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    main()
