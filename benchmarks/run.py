"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for:
  * bsi_speed          — paper Figs. 5-7 (time/voxel + speedup, tile sweep)
  * bsi_fused          — fused level-step megakernel vs the unfused
                         composition per similarity (ci preset)
  * bsi_accuracy       — paper Tables 3-4 (error vs float64 reference)
  * registration_bench — paper Figs. 8-9 + Table 5 (FFD time + MAE/SSIM)
  * transfer_model     — paper Appendix A (Eqs. A.1-A.4 transfer counts)
  * serving_bench      — continuous batching vs sequential register_batch
                         under a Poisson request stream (p50/p99, pairs/s)

Presets:
  * default — scaled-down volumes (CPU wall-time budget)
  * full    — the exact paper resolutions (``--full`` is an alias)
  * ci      — tiny smoke sizes; paired with ``--json BENCH_ci.json`` this is
              the CI perf-trajectory artifact, gated against the committed
              ``benchmarks/baseline_ci.json`` by ``benchmarks/compare.py``

Roofline tables (assignment §Roofline) are produced separately from the
dry-run artifacts by ``python -m repro.launch.roofline_report``.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
if str(_ROOT) not in sys.path:  # `python benchmarks/run.py` puts benchmarks/
    sys.path.insert(0, str(_ROOT))  # first, not the repo root
try:
    import repro  # noqa: F401  (installed via `pip install -e .`)
except ModuleNotFoundError:  # src-layout checkout without install
    sys.path.insert(0, str(_ROOT / "src"))


def _suites(preset):
    from benchmarks import (bsi_accuracy, bsi_speed, registration_bench,
                            serving_bench, transfer_model)
    from benchmarks.common import TINY_VOLUMES

    if preset == "ci":
        return [
            ("transfer_model", transfer_model.main),
            ("bsi_accuracy", lambda: bsi_accuracy.main(grid_pts=6,
                                                       tiles=[3, 5])),
            ("bsi_speed", lambda: bsi_speed.main(
                tiles=[3, 5], reps=2, vol_table=TINY_VOLUMES,
                volumes=tuple(TINY_VOLUMES))),
            # forward+backward per (mode, grad_impl): the custom-VJP adjoint
            # vs XLA autodiff of the same forward (ISSUE 4 acceptance rows)
            ("bsi_grad", lambda: bsi_speed.main(
                grad=True, tiles=[3, 5], reps=2, vol_table=TINY_VOLUMES,
                volumes=tuple(TINY_VOLUMES))),
            # fused level-step megakernel vs the unfused composition per
            # similarity (ISSUE 7 acceptance rows; interpret-mode on CPU)
            ("bsi_fused", lambda: bsi_speed.main(
                fused=True, tiles=[5], reps=2, vol_table=TINY_VOLUMES,
                volumes=("phantom2",))),
            ("registration_bench", lambda: registration_bench.main(
                shape=(22, 20, 18), iters=4, affine_iters=10)),
            # pluggable transform/regularizer axes: velocity + analytic
            # bending rows, and the fold-case min-Jacobian comparison
            # (velocity min_jac > 0 where displacement folds — ISSUE 8
            # acceptance)
            ("registration_transforms", lambda: registration_bench.main(
                transforms=True, shape=(22, 20, 18), iters=4,
                fold_iters=60)),
            # convergence-aware serving: steps saved + loss excess of
            # stop=ConvergenceConfig vs fixed iters (ISSUE 5 acceptance)
            ("registration_earlystop", lambda: registration_bench.main(
                earlystop=True, shape=(22, 20, 18), iters=24, batch=4)),
            # pluggable optimiser registry: second-order L-BFGS /
            # Gauss-Newton at a quarter of Adam's step budget on the
            # pure-SSD hard pair (ISSUE 10 acceptance: tol_met=yes means
            # the quarter-budget run reached <= Adam's final loss)
            ("registration_optimizers", lambda: registration_bench.main(
                optimizers=True)),
            # continuous batching (engine.serve) vs sequential
            # register_batch under a Poisson stream: asserts >= 1.5x
            # pairs/sec at <= 2% loss excess (PR 6 acceptance), and its
            # p50/p99 latency rows ride the compare.py trajectory gate
            ("serving", serving_bench.main),
        ]
    full = preset == "full"
    return [
        ("transfer_model", transfer_model.main),
        ("bsi_accuracy", bsi_accuracy.main),
        ("bsi_speed", lambda: bsi_speed.main(full=full)),
        ("registration_bench", registration_bench.main),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--preset", choices=["default", "full", "ci"],
                    default=None)
    ap.add_argument("--full", action="store_true",
                    help="alias for --preset full")
    ap.add_argument("--json", metavar="PATH",
                    help="also write all rows to PATH as JSON")
    args = ap.parse_args(argv)
    preset = args.preset or ("full" if args.full else "default")

    results = {}
    failures = []
    for name, fn in _suites(preset):
        print(f"# --- {name} ---")
        try:
            rows = fn()
            results[name] = [
                {"name": n, "us_per_call": u, "derived": d}
                for n, u, d in rows
            ]
        except Exception:
            failures.append(name)
            traceback.print_exc()

    if args.json:
        payload = {"preset": preset, "failures": failures, "suites": results}
        Path(args.json).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {args.json}")
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
