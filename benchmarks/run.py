"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for:
  * bsi_speed          — paper Figs. 5-7 (time/voxel + speedup, tile sweep)
  * bsi_accuracy       — paper Tables 3-4 (error vs float64 reference)
  * registration_bench — paper Figs. 8-9 + Table 5 (FFD time + MAE/SSIM)
  * transfer_model     — paper Appendix A (Eqs. A.1-A.4 transfer counts)

Roofline tables (assignment §Roofline) are produced separately from the
dry-run artifacts by ``python -m repro.launch.roofline_report``.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import bsi_accuracy, bsi_speed, registration_bench, transfer_model

    suites = [
        ("transfer_model", transfer_model.main),
        ("bsi_accuracy", bsi_accuracy.main),
        ("bsi_speed", lambda: bsi_speed.main(full="--full" in sys.argv)),
        ("registration_bench", registration_bench.main),
    ]
    failures = []
    for name, fn in suites:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
