"""Paper Appendix A: off-chip -> on-chip transfer-count model (Eqs. A.1-A.4).

Evaluates the analytic transfer counts for the paper's schemes and this
repo's TPU mapping (whole-grid-in-VMEM: each control point crosses HBM once,
the dense field is written once), for the five dataset volumes at the
default 5^3 tile.

CSV: name,us_per_call,derived  (derived = transfers or ratio).
"""
from __future__ import annotations

from benchmarks.common import FULL_VOLUMES, emit

N = 64          # control points per voxel neighbourhood
L = 32          # words per transaction (paper's L-word cache line)


def run(tile=5, block=(4, 4, 4)):
    T = tile**3
    rows = []
    for name, vol in FULL_VOLUMES.items():
        M = vol[0] * vol[1] * vol[2]
        no_tiles = N * M / L                       # Eq. A.1 (TV, no tiling)
        hw_interp = 8 * M / L                      # Eq. A.2 (texture HW)
        block_per_tile = N * M / (T * L)           # Eq. A.3 (TV-tiling)
        l, m, n = block
        blocks_of_tiles = ((4 + l - 1) * (4 + m - 1) * (4 + n - 1) * M
                           / (l * m * n * T * L))  # Eq. A.4 (paper TT)
        # TPU TT mapping: grid resident in VMEM -> each point read once
        ours = (M / T * 1.0 + M) / L               # grid once + field write
        rows += [
            (f"transfer_model/{name}/A1_no_tiles", 0.0, f"{no_tiles:.3g}"),
            (f"transfer_model/{name}/A2_texture_hw", 0.0, f"{hw_interp:.3g}"),
            (f"transfer_model/{name}/A3_block_per_tile", 0.0, f"{block_per_tile:.3g}"),
            (f"transfer_model/{name}/A4_blocks_of_tiles", 0.0, f"{blocks_of_tiles:.3g}"),
            (f"transfer_model/{name}/tpu_vmem_resident", 0.0, f"{ours:.3g}"),
            (f"transfer_model/{name}/tt_vs_tv_ratio", 0.0,
             f"x{block_per_tile / blocks_of_tiles:.1f}"),
            (f"transfer_model/{name}/tt_vs_texture_ratio", 0.0,
             f"x{hw_interp / blocks_of_tiles:.1f}"),
        ]
    return rows


def main():
    return emit(run(), ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    main()
