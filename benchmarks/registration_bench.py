"""Paper Figs. 8-9 + Table 5: FFD registration wall-time and quality.

Registers synthetic phantom pairs (repro.data.volumes) with (a) affine only,
(b) FFD using the baseline ``gather`` BSI, (c) FFD using the optimized
``separable`` BSI — reporting total time, the BSI share (Amdahl argument of
paper §6.2) and MAE/SSIM against the fixed volume (Table 5 analogue).

CSV: name,us_per_call,derived.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import metrics
from repro.core.registration import affine_register, ffd_register
from repro.data.volumes import make_pair

PAIRS = [("phantom_a", 0), ("phantom_b", 1)]


def run(shape=(48, 40, 36), iters=25):
    rows = []
    for name, seed in PAIRS:
        fixed, moving, _ = make_pair(shape=shape, tile=(6, 6, 6),
                                     magnitude=2.0, seed=seed)
        pre = (float(metrics.mae(moving, fixed)),
               float(metrics.ssim(moving, fixed)))
        aff = affine_register(fixed, moving, iters=30)
        res = {}
        for mode in ("gather", "separable"):
            res[mode] = ffd_register(
                fixed, moving, tile=(6, 6, 6), levels=2, iters=iters,
                mode=mode, measure_bsi_time=True,
            )
        base, opt = res["gather"], res["separable"]
        rows += [
            (f"registration/{name}/affine",
             round(aff.seconds * 1e6, 0),
             f"mae={float(metrics.mae(aff.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(aff.warped, fixed)):.4f}"),
            (f"registration/{name}/ffd_gather",
             round(base.seconds * 1e6, 0),
             f"mae={float(metrics.mae(base.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(base.warped, fixed)):.4f}"
             f"|bsi_s={base.bsi_seconds:.3f}"),
            (f"registration/{name}/ffd_separable",
             round(opt.seconds * 1e6, 0),
             f"mae={float(metrics.mae(opt.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(opt.warped, fixed)):.4f}"
             f"|bsi_s={opt.bsi_seconds:.3f}"
             f"|reg_speedup=x{base.seconds / max(opt.seconds, 1e-9):.2f}"),
            (f"registration/{name}/pre_registration", 0.0,
             f"mae={pre[0]:.4f}|ssim={pre[1]:.4f}"),
        ]
    return rows


def main():
    return emit(run(), ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    main()
