"""Paper Figs. 8-9 + Table 5: FFD registration wall-time and quality.

Registers synthetic phantom pairs (repro.data.volumes) with (a) affine only,
(b) FFD using the baseline ``gather`` BSI, (c) FFD using the optimized
``separable`` BSI, and (d) FFD using the autotuned BSI (``repro.engine``
picks the fastest form for this grid/tile) — reporting total time, the BSI
share (Amdahl argument of paper §6.2) and MAE/SSIM against the fixed volume
(Table 5 analogue).  The FFD inner loop is the engine's scan-compiled path.

A multi-modal preset rides along (paper §6's CT↔CBCT case, NiftyReg's NMI
path): the moving volume gets a monotone intensity remap before
registration, so SSD demonstrably fails while NMI recovers the warp —
quality is scored by warping the *original* moving volume with each
recovered field.

CSV: name,us_per_call,derived.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import ffd as ffd_mod
from repro.core import metrics
from repro.core.registration import affine_register, ffd_register
from repro.data.volumes import make_pair
from repro.engine.autotune import resolve_bsi

PAIRS = [("phantom_a", 0), ("phantom_b", 1)]

TILE = (6, 6, 6)


def monotone_remap(v):
    """Monotone-decreasing intensity remap (synthetic cross-modality)."""
    return (1.0 - v) ** 1.5


def run_multimodal(shape=(48, 40, 36), iters=25, similarities=("ssd", "nmi")):
    """The multi-modal rows: register (fixed, remapped moving) per similarity.

    MAE/SSIM are computed on the original (un-remapped) moving volume warped
    by the recovered field — the honest cross-modal score.
    """
    fixed, moving, _ = make_pair(shape=shape, tile=TILE,
                                 magnitude=2.0, seed=2)
    remapped = monotone_remap(moving)
    rows = [
        ("registration/multimodal/pre_registration", 0.0,
         f"mae={float(metrics.mae(moving, fixed)):.4f}"
         f"|ssim={float(metrics.ssim(moving, fixed)):.4f}"),
    ]
    for sim in similarities:
        res = ffd_register(fixed, remapped, tile=TILE, levels=2,
                           iters=iters, similarity=sim)
        disp = ffd_mod.dense_field(res.params, TILE, shape)
        recovered = ffd_mod.warp_volume(moving, disp)
        rows.append(
            (f"registration/multimodal/ffd_{sim}",
             round(res.seconds * 1e6, 0),
             f"mae={float(metrics.mae(recovered, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(recovered, fixed)):.4f}"))
    return rows


def run(shape=(48, 40, 36), iters=25, affine_iters=30, multimodal=True):
    auto_mode, auto_impl = resolve_bsi(
        "auto", "auto", ffd_mod.grid_shape_for_volume(shape, TILE), TILE,
        measure_grad=True)
    rows = []
    for name, seed in PAIRS:
        fixed, moving, _ = make_pair(shape=shape, tile=TILE,
                                     magnitude=2.0, seed=seed)
        pre = (float(metrics.mae(moving, fixed)),
               float(metrics.ssim(moving, fixed)))
        aff = affine_register(fixed, moving, iters=affine_iters)
        res = {}
        for mode, impl in (("gather", "jnp"), ("separable", "jnp"),
                           (auto_mode, auto_impl)):
            if (mode, impl) in res:
                continue
            res[(mode, impl)] = ffd_register(
                fixed, moving, tile=TILE, levels=2, iters=iters,
                mode=mode, impl=impl, measure_bsi_time=True,
            )
        base = res[("gather", "jnp")]
        opt = res[("separable", "jnp")]
        auto = res[(auto_mode, auto_impl)]
        rows += [
            (f"registration/{name}/affine",
             round(aff.seconds * 1e6, 0),
             f"mae={float(metrics.mae(aff.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(aff.warped, fixed)):.4f}"),
            (f"registration/{name}/ffd_gather",
             round(base.seconds * 1e6, 0),
             f"mae={float(metrics.mae(base.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(base.warped, fixed)):.4f}"
             f"|bsi_s={base.bsi_seconds:.3f}"),
            (f"registration/{name}/ffd_separable",
             round(opt.seconds * 1e6, 0),
             f"mae={float(metrics.mae(opt.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(opt.warped, fixed)):.4f}"
             f"|bsi_s={opt.bsi_seconds:.3f}"
             f"|reg_speedup=x{base.seconds / max(opt.seconds, 1e-9):.2f}"),
            (f"registration/{name}/ffd_auto",
             round(auto.seconds * 1e6, 0),
             f"mae={float(metrics.mae(auto.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(auto.warped, fixed)):.4f}"
             f"|chosen={auto_mode}/{auto_impl}"
             f"|reg_speedup=x{base.seconds / max(auto.seconds, 1e-9):.2f}"),
            (f"registration/{name}/pre_registration", 0.0,
             f"mae={pre[0]:.4f}|ssim={pre[1]:.4f}"),
        ]
    if multimodal:
        rows += run_multimodal(shape=shape, iters=iters)
    return rows


def main(**kwargs):
    return emit(run(**kwargs), ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    main()
