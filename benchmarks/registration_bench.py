"""Paper Figs. 8-9 + Table 5: FFD registration wall-time and quality.

Registers synthetic phantom pairs (repro.data.volumes) with (a) affine only,
(b) FFD using the baseline ``gather`` BSI, (c) FFD using the optimized
``separable`` BSI, (d) FFD using the MXU matrix form (``matmul`` BSI),
(e) FFD using the autotuned BSI (``repro.engine``
picks the fastest form for this grid/tile), and (f) FFD with the fused
level-step megakernel forced on (``fused="on"``: BSI + warp + similarity in
one VMEM pass) — reporting total time, the BSI share (Amdahl argument of
paper §6.2) and MAE/SSIM against the fixed volume (Table 5 analogue).  The
FFD inner loop is the engine's scan-compiled path.

A multi-modal preset rides along (paper §6's CT↔CBCT case, NiftyReg's NMI
path): the moving volume gets a monotone intensity remap before
registration, so SSD demonstrably fails while NMI recovers the warp —
quality is scored by warping the *original* moving volume with each
recovered field.

``--sharded`` instead reports data-parallel serving throughput: the same
batch registered via ``register_batch(..., mesh=...)`` over growing device
counts (pairs/sec vs devices — the pod-scaling curve the ROADMAP north-star
asks for).  On a 1-device CPU host it re-executes itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the curve exists
on laptops and in CI; on real accelerators it uses the devices as-is.

``--earlystop`` reports the convergence-aware serving rows (Budelmann et
al.'s stop-on-plateau, ``repro.engine.convergence``): a mixed easy/hard
batch and an all-easy batch, each registered with fixed ``iters`` and with
``stop=ConvergenceConfig(...)`` — steps saved, final-loss excess vs the
fixed run, and pairs/sec.  All timings are warm (compile-cached) runs; the
mixed batch shows the per-lane step savings at matched quality, the
all-easy batch the wall-clock win when every lane converges early and the
batched ``while_loop`` exits.

CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
if str(_ROOT) not in sys.path:  # direct execution: python benchmarks/...py
    sys.path.insert(0, str(_ROOT))
try:
    import repro  # noqa: F401  (installed via `pip install -e .`)
except ModuleNotFoundError:  # src-layout checkout without install
    sys.path.insert(0, str(_ROOT / "src"))

from benchmarks.common import emit
from repro.core import ffd as ffd_mod
from repro.core import metrics
from repro.core.options import RegistrationOptions
from repro.core.registration import affine_register, ffd_register
from repro.data.volumes import make_pair
from repro.engine.autotune import resolve_bsi

PAIRS = [("phantom_a", 0), ("phantom_b", 1)]

TILE = (6, 6, 6)


def monotone_remap(v):
    """Monotone-decreasing intensity remap (synthetic cross-modality)."""
    return (1.0 - v) ** 1.5


def run_multimodal(shape=(48, 40, 36), iters=25, similarities=("ssd", "nmi")):
    """The multi-modal rows: register (fixed, remapped moving) per similarity.

    MAE/SSIM are computed on the original (un-remapped) moving volume warped
    by the recovered field — the honest cross-modal score.
    """
    fixed, moving, _ = make_pair(shape=shape, tile=TILE,
                                 magnitude=2.0, seed=2)
    remapped = monotone_remap(moving)
    rows = [
        ("registration/multimodal/pre_registration", 0.0,
         f"mae={float(metrics.mae(moving, fixed)):.4f}"
         f"|ssim={float(metrics.ssim(moving, fixed)):.4f}"),
    ]
    for sim in similarities:
        res = ffd_register(fixed, remapped, tile=TILE, levels=2,
                           iters=iters, similarity=sim)
        disp = ffd_mod.dense_field(res.params, TILE, shape)
        recovered = ffd_mod.warp_volume(moving, disp)
        rows.append(
            (f"registration/multimodal/ffd_{sim}",
             round(res.seconds * 1e6, 0),
             f"mae={float(metrics.mae(recovered, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(recovered, fixed)):.4f}"))
    return rows


def run(shape=(48, 40, 36), iters=25, affine_iters=30, multimodal=True):
    auto_mode, auto_impl = resolve_bsi(
        "auto", "auto", ffd_mod.grid_shape_for_volume(shape, TILE), TILE,
        measure_grad=True)
    rows = []
    for name, seed in PAIRS:
        fixed, moving, _ = make_pair(shape=shape, tile=TILE,
                                     magnitude=2.0, seed=seed)
        pre = (float(metrics.mae(moving, fixed)),
               float(metrics.ssim(moving, fixed)))
        aff = affine_register(fixed, moving, iters=affine_iters)
        res = {}
        for mode, impl in (("gather", "jnp"), ("separable", "jnp"),
                           ("matmul", "jnp"), (auto_mode, auto_impl)):
            if (mode, impl) in res:
                continue
            res[(mode, impl)] = ffd_register(
                fixed, moving, tile=TILE, levels=2, iters=iters,
                mode=mode, impl=impl, measure_bsi_time=True,
            )
        # fused level step, forced on: the dense field and warped volume
        # never hit HBM (on CPU hosts the kernel runs in interpret mode —
        # a correctness-path trajectory row, not the TPU speedup story)
        fus = ffd_register(fixed, moving, options=RegistrationOptions(
            tile=TILE, levels=2, iters=iters, fused="on"))
        base = res[("gather", "jnp")]
        opt = res[("separable", "jnp")]
        mm = res[("matmul", "jnp")]
        auto = res[(auto_mode, auto_impl)]
        rows += [
            (f"registration/{name}/affine",
             round(aff.seconds * 1e6, 0),
             f"mae={float(metrics.mae(aff.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(aff.warped, fixed)):.4f}"),
            (f"registration/{name}/ffd_gather",
             round(base.seconds * 1e6, 0),
             f"mae={float(metrics.mae(base.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(base.warped, fixed)):.4f}"
             f"|bsi_s={base.bsi_seconds:.3f}"),
            (f"registration/{name}/ffd_separable",
             round(opt.seconds * 1e6, 0),
             f"mae={float(metrics.mae(opt.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(opt.warped, fixed)):.4f}"
             f"|bsi_s={opt.bsi_seconds:.3f}"
             f"|reg_speedup=x{base.seconds / max(opt.seconds, 1e-9):.2f}"),
            (f"registration/{name}/ffd_matmul",
             round(mm.seconds * 1e6, 0),
             f"mae={float(metrics.mae(mm.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(mm.warped, fixed)):.4f}"
             f"|bsi_s={mm.bsi_seconds:.3f}"
             f"|reg_speedup=x{base.seconds / max(mm.seconds, 1e-9):.2f}"),
            (f"registration/{name}/ffd_auto",
             round(auto.seconds * 1e6, 0),
             f"mae={float(metrics.mae(auto.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(auto.warped, fixed)):.4f}"
             f"|chosen={auto_mode}/{auto_impl}"
             f"|reg_speedup=x{base.seconds / max(auto.seconds, 1e-9):.2f}"),
            (f"registration/{name}/ffd_fused",
             round(fus.seconds * 1e6, 0),
             f"mae={float(metrics.mae(fus.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(fus.warped, fixed)):.4f}"
             f"|reg_speedup=x{base.seconds / max(fus.seconds, 1e-9):.2f}"),
            (f"registration/{name}/pre_registration", 0.0,
             f"mae={pre[0]:.4f}|ssim={pre[1]:.4f}"),
        ]
    if multimodal:
        rows += run_multimodal(shape=shape, iters=iters)
    return rows


def run_transforms(shape=(22, 20, 18), tile=(4, 4, 4), iters=12,
                   fold_iters=60, fold_lr=0.5, fold_magnitude=8.0):
    """Transform/regularizer rows: diffeomorphic velocity + analytic bending.

    Two sections.  (a) A standard pair registered with the classic
    displacement FFD, the stationary-velocity-field transform
    (``transform="velocity"``: scaling-and-squaring integration) and the
    analytic bending regularizer (``regularizer="bending"``, Shah et al.'s
    closed-form gradient) — time + quality + min Jacobian determinant per
    row.  (b) The IGS-safety fold case: an aggressive synthetic
    pneumoperitoneum (``fold_magnitude``) that the *unregularised*
    displacement FFD matches only by folding space (``min_jac < 0``), where
    the velocity transform (+ analytic bending) stays fold-free
    (``min_jac > 0``) at equal-or-better similarity — the acceptance
    workload of the pluggable-transform layer.
    """
    import jax.numpy as jnp

    from repro.core.regularizer import bending
    from repro.core.transform import dense_displacement, jacobian_determinant

    def min_jac(opts, params):
        disp = dense_displacement(opts.transform, params, opts.tile, shape,
                                  mode=opts.mode, impl=opts.impl)
        return float(jnp.min(jacobian_determinant(disp)))

    base = RegistrationOptions(tile=tile, levels=2, iters=iters,
                               mode="separable", impl="jnp",
                               grad_impl="xla", fused="off")
    fixed, moving, _ = make_pair(shape=shape, tile=tile, magnitude=2.0,
                                 seed=0)
    rows = []
    for name, opts in (
            ("ffd_displacement", base),
            ("ffd_velocity", base.replace(transform="velocity")),
            ("ffd_bending", base.replace(regularizer=bending(1e-3)))):
        res = ffd_register(fixed, moving, options=opts)
        rows.append(
            (f"registration/transforms/{name}",
             round(res.seconds * 1e6, 0),
             f"mae={float(metrics.mae(res.warped, fixed)):.4f}"
             f"|ssim={float(metrics.ssim(res.warped, fixed)):.4f}"
             f"|min_jac={min_jac(opts, res.params):.3f}"))

    ffold, mfold, _ = make_pair(shape=shape, tile=tile,
                                magnitude=fold_magnitude, seed=3)
    fold_base = base.replace(iters=fold_iters, lr=fold_lr,
                             bending_weight=0.0)
    disp_res = ffd_register(ffold, mfold, options=fold_base)
    vel_opts = fold_base.replace(transform="velocity",
                                 regularizer=bending(3e-3))
    vel_res = ffd_register(ffold, mfold, options=vel_opts)
    sim_disp = float(jnp.mean((disp_res.warped - ffold) ** 2))
    sim_vel = float(jnp.mean((vel_res.warped - ffold) ** 2))
    rows += [
        ("registration/transforms/fold_displacement",
         round(disp_res.seconds * 1e6, 0),
         f"sim={sim_disp:.5f}"
         f"|min_jac={min_jac(fold_base, disp_res.params):.3f}"),
        ("registration/transforms/fold_velocity",
         round(vel_res.seconds * 1e6, 0),
         f"sim={sim_vel:.5f}"
         f"|min_jac={min_jac(vel_opts, vel_res.params):.3f}"
         f"|sim_excess={sim_vel / max(sim_disp, 1e-12) - 1:+.1%}"),
    ]
    return rows


def run_earlystop(shape=(22, 20, 18), iters=24, batch=4, lr=0.1,
                  tol=3e-4, patience=8):
    """Early-stop rows: fixed-``iters`` vs ``stop=ConvergenceConfig(...)``.

    Two batches at a serving-friendly learning rate (descent is monotone,
    so the plateau rule is meaningful): ``mixed`` alternates nearly-aligned
    (magnitude 0.3) and hard (2.5) pairs — easy lanes freeze early at
    equal-or-better loss while hard lanes keep their full budget; ``easy``
    is all nearly-aligned pairs — every lane converges early, the
    ``while_loop`` exits, and the whole batch gets the wall-clock win.
    Each arm is timed on a warm (compile-cached) second call, so the rows
    never see a compile spike (``BatchRegistrationResult.compiled``).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.engine import ConvergenceConfig, register_batch

    kw = dict(tile=TILE, levels=2, iters=iters, lr=lr,
              mode="separable", impl="jnp")
    stop = ConvergenceConfig(tol=tol, patience=patience)
    budget = 2 * iters  # Adam steps per pair under fixed iters (2 levels)

    def warm(F, M, reps=5, **extra):
        register_batch(F, M, **kw, **extra)  # compile on miss
        times = []
        for _ in range(reps):
            res = register_batch(F, M, **kw, **extra)
            assert not res.compiled, "warm call must hit the program cache"
            times.append(res.seconds)
        res.seconds = float(np.median(times))  # de-noise the gated timing
        return res

    rows = []
    for name, mags in (("mixed", [(0.3, 2.5)[s % 2] for s in range(batch)]),
                       ("easy", [0.3 + 0.05 * (s % 2) for s in range(batch)])):
        pairs = [make_pair(shape=shape, tile=TILE, magnitude=m, seed=s)
                 for s, m in enumerate(mags)]
        F = jnp.stack([p[0] for p in pairs])
        M = jnp.stack([p[1] for p in pairs])
        fixed = warm(F, M)
        es = warm(F, M, stop=stop)
        steps = np.asarray(es.steps)
        saved = 1.0 - steps.sum() / (len(mags) * budget)
        # worst-lane final-loss excess vs the fixed-iters run (acceptance:
        # within 2%; negative = the early-stopped run ended better)
        excess = float((np.asarray(es.losses[:, -1])
                        / np.asarray(fixed.losses[:, -1]) - 1).max())
        rows += [
            (f"registration/earlystop/{name}_fixed",
             round(fixed.seconds * 1e6, 0),
             f"pairs_per_s={len(mags) / fixed.seconds:.2f}"
             f"|steps_per_pair={budget}"),
            (f"registration/earlystop/{name}_adaptive",
             round(es.seconds * 1e6, 0),
             f"pairs_per_s={len(mags) / es.seconds:.2f}"
             f"|steps_saved={saved:.0%}"
             f"|mean_steps={steps.sum(axis=1).mean():.1f}"
             f"|max_loss_excess={excess:+.1%}"
             f"|speedup=x{fixed.seconds / es.seconds:.2f}"),
        ]
    return rows


def run_optimizers(shape=(22, 20, 18), adam_iters=48, magnitude=2.5,
                   seed=1, lr=0.1):
    """Optimiser rows: second-order entries vs Adam on the hard pair.

    One magnitude-``magnitude`` deformation pair, pure-SSD objective (the
    regime where Adam's fixed per-coordinate step costs it the tail):
    ``ffd_adam`` runs the full ``adam_iters`` budget; ``ffd_lbfgs`` and
    ``ffd_gauss_newton`` get 25% of it, and their ``tol_met`` field
    records whether they still reached Adam's final loss — the
    steps-to-tolerance acceptance of the optimiser registry, with the
    tolerance defined as what Adam achieves with 4x the steps.  Wall-clock
    is a warm (compile-cached) median, so the rows gate cleanly in
    ``compare.py``; mind that a second-order *step* is costlier than an
    Adam step (line-search evals / CG solves), so ``speedup`` is the
    honest wall-clock ratio, not the step ratio.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.engine import register_batch

    f, m, _ = make_pair(shape=shape, tile=TILE, magnitude=magnitude,
                        seed=seed)
    F, M = jnp.stack([f]), jnp.stack([m])
    base = dict(tile=TILE, levels=2, lr=lr, bending_weight=0.0,
                mode="separable", impl="jnp")

    def warm(options, reps=3):
        register_batch(F, M, options=options)  # compile on miss
        times, res = [], None
        for _ in range(reps):
            res = register_batch(F, M, options=options)
            assert not res.compiled, "warm call must hit the program cache"
            times.append(res.seconds)
        return res, float(np.median(times))

    adam_res, adam_s = warm(RegistrationOptions(**base, iters=adam_iters))
    adam_loss = float(np.asarray(adam_res.losses)[0, -1])
    rows = [("registration/optimizers/ffd_adam",
             round(adam_s * 1e6, 0),
             f"final_loss={adam_loss:.6f}|steps_per_level={adam_iters}")]
    quarter = adam_iters // 4
    for name in ("lbfgs", "gauss_newton"):
        res, secs = warm(RegistrationOptions(**base, iters=quarter,
                                             optimizer=name))
        loss = float(np.asarray(res.losses)[0, -1])
        rows.append((f"registration/optimizers/ffd_{name}",
                     round(secs * 1e6, 0),
                     f"final_loss={loss:.6f}"
                     f"|steps_per_level={quarter}"
                     f"|steps_vs_adam=25%"
                     f"|tol_met={'yes' if loss <= adam_loss else 'NO'}"
                     f"|speedup=x{adam_s / secs:.2f}"))
    return rows


def run_sharded(shape=(24, 20, 18), iters=6, batch=8, device_counts=None):
    """Pairs/sec vs device count: ``register_batch(..., mesh=...)`` scaling.

    One warm (compile-cached) timed run per mesh size; ``dev1`` is the
    unsharded single-device baseline the speedup column is relative to.
    """
    import jax
    import jax.numpy as jnp

    from repro.engine import make_registration_mesh, register_batch

    ndev = len(jax.devices())
    counts = (sorted({n for n in (1, 2, 4, 8, 16) if n <= ndev} | {ndev})
              if device_counts is None else list(device_counts))
    pairs = [make_pair(shape=shape, tile=TILE, magnitude=2.0, seed=s)
             for s in range(batch)]
    fixed = jnp.stack([p[0] for p in pairs])
    moving = jnp.stack([p[1] for p in pairs])
    kw = dict(tile=TILE, levels=2, iters=iters, mode="separable", impl="jnp")

    rows = []
    base_pps = None
    for n in counts:
        mesh = None if n == 1 else make_registration_mesh(n)
        cold = register_batch(fixed, moving, mesh=mesh, **kw).seconds
        t0 = time.perf_counter()
        register_batch(fixed, moving, mesh=mesh, **kw)
        warm = time.perf_counter() - t0
        pps = batch / warm
        base_pps = pps if base_pps is None else base_pps
        rows.append(
            (f"registration/sharded/dev{n}",
             round(warm / batch * 1e6, 0),
             f"pairs_per_s={pps:.3f}|speedup=x{pps / base_pps:.2f}"
             f"|batch={batch}|cold_s={cold:.1f}"))
    return rows


def main(sharded=False, earlystop=False, transforms=False, optimizers=False,
         **kwargs):
    if sharded:
        rows = run_sharded(**kwargs)
    elif earlystop:
        rows = run_earlystop(**kwargs)
    elif transforms:
        rows = run_transforms(**kwargs)
    elif optimizers:
        rows = run_optimizers(**kwargs)
    else:
        rows = run(**kwargs)
    return emit(rows, ["name", "us_per_call", "derived"])


if __name__ == "__main__":
    import argparse
    import os
    import subprocess
    import sys

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sharded", action="store_true",
                    help="pairs/sec vs device count via register_batch(mesh=)")
    ap.add_argument("--earlystop", action="store_true",
                    help="fixed-iters vs stop=ConvergenceConfig rows "
                         "(steps saved + loss excess on mixed/easy batches)")
    ap.add_argument("--transforms", action="store_true",
                    help="velocity-transform + analytic-bending rows incl. "
                         "the fold-case min-Jacobian comparison")
    ap.add_argument("--optimizers", action="store_true",
                    help="optimizer-registry rows: ffd_lbfgs / "
                         "ffd_gauss_newton at 25% of ffd_adam's steps "
                         "(steps-to-tolerance + wall-clock)")
    # None -> each path keeps its own defaults (run(): the paper-analogue
    # (48, 40, 36) x 25 iters; run_sharded(): a CPU-budget (24, 20, 18) x 6;
    # run_earlystop(): (22, 20, 18) x 24)
    ap.add_argument("--shape", type=int, nargs=3, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None,
                    help="batch size for --sharded / --earlystop")
    args = ap.parse_args()

    kwargs = {}
    if args.shape is not None:
        kwargs["shape"] = tuple(args.shape)
    if args.iters is not None:
        kwargs["iters"] = args.iters

    if args.transforms:
        main(transforms=True, **kwargs)
    elif args.optimizers:
        main(optimizers=True, **kwargs)
    elif args.earlystop:
        main(earlystop=True,
             **({"batch": args.batch} if args.batch is not None else {}),
             **kwargs)
    elif args.sharded:
        import jax

        flags = os.environ.get("XLA_FLAGS", "")
        if (jax.default_backend() == "cpu" and len(jax.devices()) == 1
                and "xla_force_host_platform_device_count" not in flags):
            # fake an 8-device pod and re-exec: the flag must be exported
            # before jax initialises, which already happened in this process
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8").strip()
            sys.exit(subprocess.call([sys.executable, __file__]
                                     + sys.argv[1:], env=env))
        main(sharded=True, batch=args.batch or 8, **kwargs)
    else:
        main(**kwargs)
