"""engine.serve: lane recycling correctness, bucketing, and failure modes.

The load-bearing claim of continuous batching is that splicing a queued
pair into a lane freed mid-flight changes *scheduling*, not *results*: a
recycled request must match a solo ``ffd_register`` of the same pair.
Everything time-dependent runs under a fake clock so deadlines are
deterministic (device work still runs; only the scheduler's notion of
"now" is faked).
"""
import asyncio

import numpy as np
import pytest

from repro.core.options import RegistrationOptions
from repro.core.registration import ffd_register
from repro.engine.convergence import ConvergenceConfig
from repro.engine.serve import (AsyncRegistrationService, QueueFull,
                                RegistrationScheduler, RegistrationTimeout)

SHAPE = (22, 20, 18)
OPTS = RegistrationOptions(
    tile=(6, 6, 6), levels=2, iters=16, lr=0.1,
    mode="separable", impl="jnp", grad_impl="xla",
    stop=ConvergenceConfig(tol=2e-3, patience=3))


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _mixed_pairs(n, shape=SHAPE, hard_every=3, seed=0):
    """Every ``hard_every``-th pair needs the full budget; the rest plateau
    within a few steps — the contrast that makes lanes free mid-flight."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=shape).astype(np.float32)
    x, y, z = np.meshgrid(*[np.linspace(0, np.pi, s) for s in shape],
                          indexing="ij")
    wave = (np.sin(x) * np.sin(y) * np.sin(z)).astype(np.float32)
    out = []
    for i in range(n):
        f = base + 0.05 * rng.normal(size=shape).astype(np.float32)
        if i % hard_every == 0:
            m = np.roll(f, 3, axis=0) + 2.5 * wave
            m = m + 0.3 * rng.normal(size=shape).astype(np.float32)
        else:
            m = f + 0.02 * wave
        out.append((f, m.astype(np.float32)))
    return out


class TestRecycling:
    def test_recycled_matches_solo(self):
        """Requests spliced into mid-flight lane arrays reproduce solo
        ``ffd_register`` step counts exactly and warps to <= 1e-5 (the
        chunked vmapped scan fuses differently from the solo while_loop,
        so the last float digits may differ — trajectories do not)."""
        pairs = _mixed_pairs(6)
        sched = RegistrationScheduler(OPTS, lanes=2, chunk=3, max_queue=16)
        handles = [sched.submit(f, m) for f, m in pairs]
        sched.run_until_idle()
        assert sched.stats.recycled >= 1
        assert sched.stats.completed == len(pairs)
        recycled_seen = 0
        for (f, m), h in zip(pairs, handles):
            served = h.result()
            solo = ffd_register(f, m, options=OPTS)
            assert served.steps == solo.steps
            np.testing.assert_allclose(np.asarray(served.warped),
                                       np.asarray(solo.warped), atol=1e-5)
            recycled_seen += served.recycled
        assert recycled_seen == sched.stats.recycled

    def test_chunk_width_never_changes_trajectories(self):
        """chunk only sets when the host looks: step counts are identical
        across chunk widths (warps again to fusion-level 1e-5)."""
        f, m = _mixed_pairs(1)[0]
        results = []
        for chunk in (1, 5):
            sched = RegistrationScheduler(OPTS, lanes=2, chunk=chunk)
            h = sched.submit(f, m)
            sched.run_until_idle()
            results.append(h.result())
        assert results[0].steps == results[1].steps
        np.testing.assert_allclose(np.asarray(results[0].warped),
                                   np.asarray(results[1].warped), atol=1e-5)


class TestBucketing:
    def test_one_compile_per_shape_and_level(self):
        shapes = [SHAPE, (18, 16, 14)]
        sched = RegistrationScheduler(OPTS, lanes=2, chunk=4)
        rng = np.random.default_rng(1)
        for shape in shapes:
            for _ in range(2):
                f = rng.normal(size=shape).astype(np.float32)
                sched.submit(f, np.roll(f, 1, axis=0))
        sched.run_until_idle()
        assert sched.stats.buckets == len(shapes)
        assert sched.stats.compiles == OPTS.levels * len(shapes)
        assert sched.stats.completed == 2 * len(shapes)

    def test_shape_mismatch_rejected(self):
        sched = RegistrationScheduler(OPTS)
        f = np.zeros(SHAPE, np.float32)
        with pytest.raises(ValueError, match="equal shapes"):
            sched.submit(f, np.zeros((18, 16, 14), np.float32))


class TestFailureModes:
    def test_timeout_is_clean(self):
        clock = FakeClock()
        sched = RegistrationScheduler(OPTS, lanes=1, chunk=4,
                                      timeout=5.0, clock=clock)
        f, m = _mixed_pairs(1)[0]
        h = sched.submit(f, m)
        clock.advance(10.0)  # deadline passes while still queued
        sched.step()
        assert h.done and sched.pending == 0
        assert sched.stats.timed_out == 1
        with pytest.raises(RegistrationTimeout, match="expired"):
            h.result()

    def test_unexpired_requests_complete_under_fake_clock(self):
        clock = FakeClock()
        sched = RegistrationScheduler(OPTS, lanes=1, timeout=60.0,
                                      clock=clock)
        f, m = _mixed_pairs(1)[0]
        h = sched.submit(f, m)
        sched.run_until_idle()
        assert h.result().warped is not None
        assert sched.stats.timed_out == 0

    def test_backpressure_queue_full(self):
        sched = RegistrationScheduler(OPTS, lanes=1, max_queue=1)
        f, m = _mixed_pairs(1)[0]
        sched.submit(f, m)
        with pytest.raises(QueueFull, match="max_queue"):
            sched.submit(f, m)
        assert sched.stats.rejected == 1
        sched.run_until_idle()  # the admitted request still completes
        assert sched.stats.completed == 1

    def test_result_before_done_raises(self):
        sched = RegistrationScheduler(OPTS, lanes=1)
        f, m = _mixed_pairs(1)[0]
        h = sched.submit(f, m)
        with pytest.raises(RuntimeError, match="in flight"):
            h.result()
        sched.run_until_idle()
        assert h.result() is not None

    def test_constructor_validation(self):
        with pytest.raises(TypeError, match="RegistrationOptions"):
            RegistrationScheduler({"iters": 3})
        with pytest.raises(ValueError, match="lanes"):
            RegistrationScheduler(OPTS, lanes=0)
        with pytest.raises(ValueError, match="chunk"):
            RegistrationScheduler(OPTS, chunk=0)


class TestAsyncFacade:
    def test_concurrent_registers(self):
        pairs = _mixed_pairs(3)

        async def run():
            service = AsyncRegistrationService(
                scheduler=RegistrationScheduler(OPTS, lanes=2, chunk=4))
            return await asyncio.gather(
                *(service.register(f, m) for f, m in pairs))

        results = asyncio.run(run())
        assert len(results) == len(pairs)
        for (f, m), served in zip(pairs, results):
            solo = ffd_register(f, m, options=OPTS)
            np.testing.assert_allclose(np.asarray(served.warped),
                                       np.asarray(solo.warped), atol=1e-5)
