"""Training substrate: loss oracle, optimizer numerics, schedules, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.layers import chunked_xent
from repro.optim.optimizer import OptConfig, global_norm, lr_at, opt_init, opt_update


def test_chunked_xent_matches_direct():
    rng = np.random.default_rng(0)
    B, S, D, V = 2, 13, 8, 50      # S deliberately not a chunk multiple
    h = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    table = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    # mask a few positions
    labels = labels.at[0, :3].set(-1)

    logits = jnp.einsum("bsd,vd->bsv", h, table)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, jnp.clip(labels, 0)[..., None], -1)[..., 0]
    mask = (labels >= 0)
    direct = jnp.sum((lse - gold) * mask) / jnp.sum(mask)

    for chunk in (4, 5, 13, 64):
        out = chunked_xent(h, table, labels, chunk=chunk)
        np.testing.assert_allclose(float(out), float(direct), rtol=1e-5)


def test_chunked_xent_softcap():
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.standard_normal((1, 8, 4)), jnp.float32)
    table = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 16, (1, 8)), jnp.int32)
    a = chunked_xent(h, table, labels, chunk=4, final_softcap=0.0)
    b = chunked_xent(h, table, labels, chunk=4, final_softcap=5.0)
    assert abs(float(a) - float(b)) > 1e-6  # softcap changes the loss


def test_opt_update_matches_reference_adam():
    ocfg = OptConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                     clip_norm=1e9, warmup_steps=0, total_steps=10**9)
    rng = np.random.default_rng(2)
    p = {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)}
    opt = opt_init(p, ocfg)
    new_p, new_opt, stats = opt_update(g, opt, p, ocfg)
    # reference: first Adam step = -lr_sched * sign-ish update
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mh, vh = m / 0.1, v / 0.001
    lr = float(lr_at(jnp.asarray(1), ocfg))
    ref = np.asarray(p["w"]) - lr * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)


def test_lr_schedule_shape():
    ocfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(jnp.asarray(s), ocfg)) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.05          # reaches peak after warmup
    assert lrs[-1] < 0.2                        # decays
    # monotone warmup, then cosine decay begins
    assert lrs[1] <= lrs[2] and lrs[2] >= lrs[3]


def test_clipping_bounds_update():
    ocfg = OptConfig(lr=1.0, clip_norm=0.5, warmup_steps=0, total_steps=10**9,
                     weight_decay=0.0)
    p = {"w": jnp.zeros((3,), jnp.float32)}
    g = {"w": jnp.asarray([1000.0, 0.0, 0.0], jnp.float32)}
    opt = opt_init(p, ocfg)
    _, _, stats = opt_update(g, opt, p, ocfg)
    assert float(stats["grad_norm"]) == pytest.approx(1000.0)
    # the applied gradient was rescaled to norm 0.5 before the moment update


def test_bf16_moments_roundtrip():
    ocfg = OptConfig(moment_dtype="bfloat16")
    p = {"w": jnp.ones((4,), jnp.float32)}
    opt = opt_init(p, ocfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4,), 0.1, jnp.float32)}
    new_p, new_opt, _ = opt_update(g, opt, p, ocfg)
    assert new_opt["v"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))


def test_generate_greedy_deterministic():
    from repro.launch.serve import generate
    from repro.models import model as M

    cfg = get_config("internlm2-1.8b", smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32",
                           "kv_cache_dtype": "float32"})
    params = M.init_model(cfg, seed=0)
    prompts = np.ones((2, 4), np.int32)
    t1, _ = generate(cfg, params, prompts, 16, 6)
    t2, _ = generate(cfg, params, prompts, 16, 6)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 6)


def test_compressed_train_step_runs():
    from repro.data.pipeline import PipelineConfig, TokenPipeline
    from repro.optim.compression import make_compressor
    from repro.training.steps import init_train_state, make_train_step

    cfg = get_config("internlm2-1.8b", smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    state = init_train_state(cfg, ocfg)
    state["ef"] = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
    step = jax.jit(make_train_step(cfg, ocfg, compressor=make_compressor()))
    pipe = TokenPipeline(PipelineConfig(cfg.vocab_size, 32, 4))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))
    # error-feedback buffer is being used (nonzero after a step)
    ef_norm = float(global_norm(state["ef"]))
    assert ef_norm > 0
