"""Per-arch smoke tests: reduced configs, one forward/train/decode step on CPU.

Asserts output shapes and finiteness (no NaNs) for every assigned arch —
the full configs are exercised only via the dry-run (no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, input_specs, list_configs
from repro.models import model as M

ARCHS = [
    "qwen1.5-32b", "gemma3-1b", "gemma2-2b", "internlm2-1.8b",
    "qwen2-moe-a2.7b", "arctic-480b", "xlstm-1.3b", "hymba-1.5b",
    "whisper-base", "llama-3.2-vision-90b",
]


def _smoke_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frame_embeddings"] = jnp.asarray(
            rng.standard_normal((B, S // cfg.encoder_seq_divisor, cfg.d_model)),
            jnp.float32,
        )
    if cfg.family == "vlm":
        batch["image_embeddings"] = jnp.asarray(
            rng.standard_normal((B, cfg.img_tokens, cfg.d_model)), jnp.float32
        )
    return batch


def test_all_archs_registered():
    names = list_configs()
    for a in ARCHS:
        assert a in names, a


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = M.init_model(cfg, seed=0)
    batch = _smoke_batch(cfg)
    h, aux = M.forward_train(params, batch, cfg)
    assert h.shape == (2, 32, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))
    loss, parts = M.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads(arch):
    cfg = get_config(arch, smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = M.init_model(cfg, seed=0)
    batch = _smoke_batch(cfg)
    (loss, _), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg), has_aux=True
    )(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2)
            for g in jax.tree_util.tree_leaves(grads))
    )
    assert np.isfinite(float(loss))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = M.init_model(cfg, seed=0)
    B, max_len = 2, 16
    cache = M.init_decode_cache(cfg, B, max_len)
    if cfg.family == "encdec":  # cross K/V would come from the encoder
        cache["cross_k"] = jnp.ones_like(cache["cross_k"]) * 0.01
        cache["cross_v"] = jnp.ones_like(cache["cross_v"]) * 0.01
    if cfg.family == "vlm":
        cache["cross_k"] = jnp.ones_like(cache["cross_k"]) * 0.01
        cache["cross_v"] = jnp.ones_like(cache["cross_v"]) * 0.01
    tokens = jnp.ones((B, 1), jnp.int32)
    logits, cache = M.decode_step(params, cache, tokens, cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == 1
    # a second step advances the cache
    logits2, cache = M.decode_step(params, cache, tokens, cfg)
    assert int(cache["pos"]) == 2
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["gemma2-2b", "qwen2-moe-a2.7b", "hymba-1.5b",
                                  "whisper-base", "llama-3.2-vision-90b",
                                  "xlstm-1.3b"])
def test_prefill_then_decode_consistency(arch):
    """Prefill(S tokens) then decode token S must match pure forward logits.

    f32 KV cache isolates path consistency from cache rounding (bf16/int8
    cache error is covered by ``test_decode_int8_cache_close_to_bf16``);
    capacity_factor=8 disables MoE token dropping, which is legitimately
    position-dependent and would otherwise differ between the two paths.
    """
    cfg = get_config(arch, smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32", "remat": False,
                           "kv_cache_dtype": "float32", "capacity_factor": 8.0})
    params = M.init_model(cfg, seed=0)
    rng = np.random.default_rng(0)
    B, S = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    extras = {}
    if cfg.family == "encdec":
        # same encoder input for both paths; decode uses the cached cross K/V
        extras["frame_embeddings"] = jnp.asarray(
            rng.standard_normal((B, S // cfg.encoder_seq_divisor, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        extras["image_embeddings"] = jnp.asarray(
            rng.standard_normal((B, cfg.img_tokens, cfg.d_model)), jnp.float32)
    # ground truth: full forward over S+1 tokens, logits at position S
    h, _ = M.forward_train(params, {"tokens": toks, **extras}, cfg)
    table = params.get("lm_head", params["embed"]["table"])
    ref_logits = h[:, -1].astype(jnp.float32) @ table.T.astype(jnp.float32)
    if cfg.final_logit_softcap:
        ref_logits = jnp.tanh(ref_logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    # prefill on S tokens, then decode the (S+1)-th
    _, cache = M.prefill(params, {"tokens": toks[:, :S], **extras}, cfg,
                         max_len=S + 4)
    logits, _ = M.decode_step(params, cache, toks[:, S:S + 1], cfg)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(ref_logits), atol=2e-3, rtol=2e-3
    )


def test_decode_int8_cache_close_to_bf16():
    cfg = get_config("internlm2-1.8b", smoke=True)
    base = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    quant = base.__class__(**{**base.__dict__, "kv_cache_dtype": "int8"})
    params = M.init_model(base, seed=0)
    tokens = jnp.ones((1, 1), jnp.int32)
    out = {}
    for name, c in [("base", base), ("quant", quant)]:
        cache = M.init_decode_cache(c, 1, 8)
        logits = None
        for _ in range(4):
            logits, cache = M.decode_step(params, cache, tokens, c)
        out[name] = np.asarray(logits)
    err = np.max(np.abs(out["base"] - out["quant"]))
    rng_mag = np.max(np.abs(out["base"])) + 1e-9
    assert err / rng_mag < 0.1, err / rng_mag


def test_input_specs_cover_all_cells():
    n_cells = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            assert specs["tokens"].shape[0] == shape.global_batch
            n_cells += 1
    assert n_cells == 40
