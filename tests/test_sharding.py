"""Sharding rules, spec sanitisation, and pipeline parallelism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.configs.base import get_config
from repro.distributed.sharding import (
    DECODE_RULES, LONG_CONTEXT_RULES, REGISTRATION_RULES, TRAIN_RULES,
    abstract_mesh, dedup_specs, partition_specs, sanitize_specs,
)
from repro.models import model as M
from repro.models.schema import abstract_params


def _mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_rules_cover_all_logical_axes():
    r = TRAIN_RULES(("data", "model"))
    for ax in ("batch", "embed", "heads", "ff", "vocab", "experts", "seq"):
        assert ax in r
    r2 = TRAIN_RULES(("pod", "data", "model"))
    assert r2["batch"] == ("pod", "data")
    assert DECODE_RULES(("data", "model"))["kv_len"] == "model"
    assert LONG_CONTEXT_RULES(("data", "model"))["batch"] is None
    # registration serving: batch over data, all per-pair axes replicated
    rr = REGISTRATION_RULES(("data",))
    assert rr["batch"] == ("data",)
    assert rr.spec(("batch", "vol_x", "vol_y", "vol_z")) == \
        PS(("data",), None, None, None)
    assert REGISTRATION_RULES(("pod", "data"))["batch"] == ("pod", "data")


def test_sanitize_drops_nondivisible_and_duplicates():
    mesh = abstract_mesh((2, 2), ("data", "model"))
    leaf = jax.ShapeDtypeStruct((6, 3), jnp.float32)  # 6 % 2 == 0, 3 % 2 != 0
    spec = PS("data", "model")
    out = sanitize_specs(leaf, spec, mesh)
    assert out == PS("data", None)
    # duplicate axis across dims: second occurrence dropped
    leaf2 = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    out2 = sanitize_specs(leaf2, PS("data", "data"), mesh)
    assert out2 == PS("data", None)


def test_dedup_specs():
    out = dedup_specs(PS(None, "data", "data", "model"))
    assert out == PS(None, "data", None, "model")


@pytest.mark.parametrize("arch", ["qwen1.5-32b", "arctic-480b", "xlstm-1.3b",
                                  "llama-3.2-vision-90b"])
def test_param_specs_structurally_match(arch):
    """Every parameter leaf gets a spec of matching rank."""
    cfg = get_config(arch)
    schema = M.model_schema(cfg)
    specs = partition_specs(schema, TRAIN_RULES(("data", "model")))
    ab = abstract_params(schema)
    flat_a = jax.tree_util.tree_leaves(ab)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, PS))
    assert len(flat_a) == len(flat_s)
    for a, s in zip(flat_a, flat_s):
        assert len(s) == len(a.shape), (a.shape, s)


def test_head_dims_flat_divisible_by_16():
    """The flattened H*hd layout is 16-divisible for every assigned arch
    (the reason attention params store heads fused — DESIGN.md §5)."""
    for arch in ["qwen1.5-32b", "gemma3-1b", "gemma2-2b", "internlm2-1.8b",
                 "qwen2-moe-a2.7b", "arctic-480b", "hymba-1.5b",
                 "whisper-base", "llama-3.2-vision-90b"]:
        cfg = get_config(arch)
        hd = cfg.resolved_head_dim
        assert (cfg.num_heads * hd) % 16 == 0, arch
        assert (cfg.num_kv_heads * hd) % 16 == 0, arch


def test_pipeline_parallel_matches_serial():
    """GPipe stage runner == serial layer stack (1-stage degenerate + math
    identity on a single-device 'pp' axis)."""
    from repro.distributed.pipeline import pipeline_apply

    mesh = jax.make_mesh((1,), ("pp",))
    rng = np.random.default_rng(0)
    n_stages, d = 1, 8
    w = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, d)), jnp.float32)

    def stage(params, h):
        return jnp.tanh(h @ params)

    out = pipeline_apply(stage, w, x, mesh=mesh, axis="pp", n_micro=2)
    ref = x
    for i in range(n_stages):
        ref = jnp.tanh(ref @ w[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_cache_specs_match_cache_structure():
    for arch in ["gemma2-2b", "xlstm-1.3b", "whisper-base",
                 "llama-3.2-vision-90b", "hymba-1.5b"]:
        cfg = get_config(arch, smoke=True)
        cache = M.abstract_cache(cfg, 2, 16)
        specs = M.cache_partition_specs(cfg, DECODE_RULES(("data", "model")))
        flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
        flat_s = {jax.tree_util.keystr(p): s for p, s in
                  jax.tree_util.tree_flatten_with_path(
                      specs, is_leaf=lambda x: isinstance(x, PS))[0]}
        for path, leaf in flat_c:
            key = jax.tree_util.keystr(path)
            assert key in flat_s, key
            assert len(flat_s[key]) <= len(leaf.shape), (key, leaf.shape)


def test_pipeline_parallel_multistage_subprocess():
    """4-stage pipeline vs serial — needs 4 devices, so runs in a fresh
    process with forced host devices (same trick as the dry-run)."""
    import subprocess, sys, os, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply
        mesh = jax.make_mesh((4,), ("pp",))
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((4, 8, 8)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
        out = pipeline_apply(lambda p, h: jnp.tanh(h @ p), w, x,
                             mesh=mesh, axis="pp", n_micro=4)
        ref = x
        for i in range(4):
            ref = jnp.tanh(ref @ w[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        print("PIPELINE_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "PIPELINE_OK" in r.stdout, r.stderr[-2000:]
