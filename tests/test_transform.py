"""Transform & regularizer layer: diffeomorphic velocity fields + analytic
bending energy behind the shared registry API.

Covers the ISSUE-8 acceptance points: velocity invertibility (forward ∘
inverse under a voxel-milli tolerance), fold-freedom (min Jacobian
determinant > 0) on a pair where displacement-FFD folds — at no
similarity-loss excess — the analytic bending gradient matching autodiff of
the energy, ``stop=`` / ``vmap`` / mesh parity for the velocity transform,
and ``fused="on" + velocity`` raising.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ffd
from repro.core.options import RegistrationOptions
from repro.core.registration import ffd_register
from repro.core.registry import Registry
from repro.core.regularizer import (REGULARIZERS, BendingRegularizer,
                                    available_regularizers, bending,
                                    bending_energy_fn, bending_gram_matrices,
                                    regularizer_term, regularizer_token,
                                    resolve_regularizer)
from repro.core.similarity import (SIMILARITIES, available_similarities,
                                   resolve_similarity, ssd)
from repro.core.transform import (TRANSFORMS, VelocityTransform,
                                  available_transforms, compose_displacement,
                                  dense_displacement, jacobian_determinant,
                                  resolve_transform, scaling_and_squaring,
                                  transform_token, velocity)
from repro.data.volumes import make_pair
from repro.engine.batch import ffd_level_loss, register_batch
from repro.engine.convergence import ConvergenceConfig

# concrete BSI axes: no autotune variance, one compile per shape
CONCRETE = dict(mode="separable", impl="jnp", grad_impl="xla", fused="off")


def _smooth_velocity_grid(gshape, scale=0.5):
    """A smooth (sinusoidal) velocity control grid — low curvature, so the
    trilinear composition error of scaling-and-squaring stays tiny."""
    ii, jj, kk = np.meshgrid(*(np.arange(n) for n in gshape), indexing="ij")
    base = np.stack([np.sin(0.6 * ii + 0.3 * jj),
                     np.cos(0.5 * jj + 0.2 * kk),
                     np.sin(0.4 * kk + 0.25 * ii)], axis=-1)
    return jnp.asarray(scale * base, jnp.float32)


# --- the shared registry helper ---------------------------------------------


class TestRegistry:
    def test_unknown_name_lists_options(self):
        reg = Registry("widget")
        reg.register("a", 1)
        reg.register("b", 2)
        with pytest.raises(ValueError, match=r"unknown widget 'c'.*'a', 'b'"):
            reg.get("c")
        assert reg.names() == ["a", "b"]
        assert "a" in reg and "c" not in reg

    def test_registered_value_canonicalises_to_its_name(self):
        reg = Registry("widget")
        obj = object()
        reg.register("x", obj)
        assert reg.resolve("x") == ("x", obj)
        assert reg.resolve(obj) == ("x", obj)

    def test_passthrough_predicate(self):
        reg = Registry("widget", passthrough=callable, hint="or a callable")
        fn = lambda: None  # noqa: E731
        key, val = reg.resolve(fn)
        assert key is fn and val is fn
        with pytest.raises(ValueError, match="or a callable"):
            reg.resolve(123)

    def test_similarity_public_surface_unchanged(self):
        # similarity.py migrated onto Registry with its exact public API
        assert isinstance(SIMILARITIES, Registry)
        assert set(available_similarities()) >= {"ssd", "ncc", "lncc", "nmi"}
        key, fn = resolve_similarity("ssd")
        assert key == "ssd" and fn is ssd
        custom = lambda w, f: jnp.mean(jnp.abs(w - f))  # noqa: E731
        key, fn = resolve_similarity(custom)
        assert key is custom and fn is custom
        with pytest.raises(ValueError, match="unknown similarity"):
            resolve_similarity("nope")

    def test_transform_and_regularizer_registries(self):
        assert available_transforms() == ["displacement", "velocity"]
        assert available_regularizers() == ["bending", "none"]
        assert resolve_transform("velocity") == VelocityTransform()
        assert resolve_transform(velocity(squarings=4)).squarings == 4
        assert resolve_regularizer("bending") == BendingRegularizer()
        assert TRANSFORMS.resolve(VelocityTransform())[0] == "velocity"
        assert REGULARIZERS.resolve(BendingRegularizer())[0] == "bending"
        with pytest.raises(ValueError, match="unknown transform"):
            resolve_transform("affine")
        with pytest.raises(ValueError, match="unknown regularizer"):
            resolve_regularizer("tv")

    def test_tokens_and_spec_validation(self):
        assert transform_token("displacement") == "displacement"
        assert transform_token(velocity(4)) == "velocity(squarings=4)"
        assert regularizer_token("none") == "none"
        assert regularizer_token(bending(2e-3)) == "bending(weight=0.002)"
        with pytest.raises(ValueError):
            velocity(squarings=0)
        with pytest.raises(ValueError):
            bending(weight=-1.0)


# --- velocity transform mechanics -------------------------------------------


class TestVelocity:
    def test_invertibility(self):
        """forward ∘ inverse displacement stays under 1e-3 voxels inside."""
        tile, vol = (8, 8, 8), (40, 40, 40)
        gshape = ffd.grid_shape_for_volume(vol, tile)
        phi = _smooth_velocity_grid(gshape)
        fwd = dense_displacement("velocity", phi, tile, vol, **{
            k: CONCRETE[k] for k in ("mode", "impl", "grad_impl")})
        inv = dense_displacement("velocity", phi, tile, vol, inverse=True, **{
            k: CONCRETE[k] for k in ("mode", "impl", "grad_impl")})
        assert float(jnp.max(jnp.abs(fwd))) > 0.2  # a real deformation
        resid = compose_displacement(inv, fwd)  # (id+inv) ∘ (id+fwd) - id
        interior = jnp.abs(resid)[2:-2, 2:-2, 2:-2]
        assert float(jnp.max(interior)) <= 1e-3

    def test_scaling_and_squaring_small_field_is_near_linear(self):
        # exp(v) ≈ v for tiny v: the integrator must not distort it
        tile, vol = (6, 6, 6), (18, 18, 18)
        gshape = ffd.grid_shape_for_volume(vol, tile)
        phi = _smooth_velocity_grid(gshape, scale=1e-3)
        vel_field = ffd.dense_field(phi, tile, vol)
        integrated = scaling_and_squaring(vel_field, 6)
        assert float(jnp.max(jnp.abs(integrated - vel_field))) < 1e-5

    def test_jacobian_determinant_identity_and_fold(self):
        disp = jnp.zeros((8, 8, 8, 3), jnp.float32)
        assert np.allclose(np.asarray(jacobian_determinant(disp)), 1.0)
        # u_x = -2x reflects the x axis: det(J) = 1 - 2 = -1 everywhere
        x = jnp.arange(8, dtype=jnp.float32)
        fold = disp.at[..., 0].set(-2.0 * x[:, None, None])
        assert np.allclose(np.asarray(jacobian_determinant(fold)), -1.0)

    def test_displacement_has_no_inverse(self):
        phi = jnp.zeros((5, 5, 5, 3), jnp.float32)
        with pytest.raises(ValueError, match="no analytic"):
            dense_displacement("displacement", phi, (4, 4, 4), (8, 8, 8),
                               inverse=True)


# --- the analytic bending energy --------------------------------------------


class TestBendingEnergy:
    def test_gram_matrices_symmetric_and_partition_of_unity(self):
        for n in (5, 8, 11):
            g0, g1, g2 = (np.asarray(g) for g in bending_gram_matrices(n))
            for g in (g0, g1, g2):
                assert np.allclose(g, g.T, atol=1e-6)
            # Σ_i β(s-i+1) = 1 on the domain, so G⁰'s total mass is the
            # domain length T = n - 3 and G¹/G² rows of the constant
            # coefficient vector annihilate (derivatives of a constant)
            ones = np.ones(n)
            assert np.isclose(ones @ g0 @ ones, n - 3, atol=1e-5)
            assert np.isclose(ones @ g1 @ ones, 0.0, atol=1e-6)
            assert np.isclose(ones @ g2 @ ones, 0.0, atol=1e-6)

    def test_energy_zero_for_constant_and_linear_fields(self):
        energy = bending_energy_fn((8, 7, 9), (5, 5, 5))
        const = jnp.ones((8, 7, 9, 3), jnp.float32) * 2.5
        assert abs(float(energy(const))) < 1e-8
        ii = jnp.arange(8, dtype=jnp.float32)[:, None, None, None]
        linear = jnp.broadcast_to(0.3 * ii, (8, 7, 9, 3))
        assert abs(float(energy(linear))) < 1e-6

    def test_analytic_gradient_matches_autodiff(self):
        """The closed-form ∇E = 2Qφ custom VJP == autodiff of the energy."""
        energy = bending_energy_fn((10, 9, 11), (4, 5, 6))
        rng = np.random.default_rng(0)
        phi = jnp.asarray(rng.standard_normal((10, 9, 11, 3)), jnp.float32)
        g_analytic = jax.grad(energy)(phi)
        g_autodiff = jax.grad(energy.reference)(phi)
        denom = max(float(jnp.max(jnp.abs(g_autodiff))), 1e-12)
        rel = float(jnp.max(jnp.abs(g_analytic - g_autodiff))) / denom
        assert rel <= 1e-5

    def test_none_term_is_the_legacy_proxy(self):
        rng = np.random.default_rng(1)
        phi = jnp.asarray(rng.standard_normal((7, 8, 6, 3)), jnp.float32)
        term = regularizer_term("none", grid_shape=(7, 8, 6), tile=(5, 5, 5),
                                bending_weight=5e-3)
        expect = 5e-3 * ffd.bending_energy(phi)
        assert float(term(phi)) == float(expect)  # bit-identical

    def test_bending_term_replaces_proxy_at_factory_weight(self):
        rng = np.random.default_rng(2)
        phi = jnp.asarray(rng.standard_normal((7, 8, 6, 3)), jnp.float32)
        energy = bending_energy_fn((7, 8, 6), (5, 5, 5))
        term = regularizer_term(bending(2e-3), grid_shape=(7, 8, 6),
                                tile=(5, 5, 5), bending_weight=123.0)
        assert np.isclose(float(term(phi)), 2e-3 * float(energy(phi)),
                          rtol=1e-6)


# --- the registered axes through the registration stack ---------------------


class TestRegistrationIntegration:
    def test_velocity_fold_free_where_displacement_folds(self):
        """The IGS-safety workload: an aggressive synthetic pneumoperitoneum
        that classic FFD can only match by folding space; the velocity
        transform (+ analytic bending) stays diffeomorphic (min Jacobian
        determinant > 0) at no similarity cost (well under the 5% excess
        budget — it is in fact better)."""
        shape, tile = (22, 20, 18), (4, 4, 4)
        fixed, moving, _ = make_pair(shape, tile=tile, magnitude=8.0, seed=3)
        # bending_weight=0: the raw FFD objective, which matches this pair
        # only by folding; the velocity run swaps in the analytic bending
        # regularizer (which ignores the legacy proxy weight entirely)
        opts = RegistrationOptions(tile=tile, levels=2, iters=60, lr=0.5,
                                   bending_weight=0.0, **CONCRETE)
        r_disp = ffd_register(fixed, moving, options=opts)
        r_vel = ffd_register(fixed, moving, options=opts.replace(
            transform="velocity", regularizer=bending(3e-3)))

        def min_jac(opts1, phi):
            disp = dense_displacement(opts1.transform, phi, tile, shape,
                                      mode=opts1.mode, impl=opts1.impl)
            return float(jnp.min(jacobian_determinant(disp)))

        def sim(res):
            return float(jnp.mean((res.warped - fixed) ** 2))

        mj_disp = min_jac(opts, r_disp.params)
        mj_vel = min_jac(opts.replace(transform="velocity"), r_vel.params)
        assert mj_disp < 0.0          # classic FFD folds on this pair
        assert mj_vel > 0.0           # the velocity warp stays orientation-
        #                               preserving everywhere
        assert sim(r_vel) <= 1.05 * sim(r_disp)  # <= 5% similarity excess

    def test_velocity_vmap_parity(self):
        """register_batch's vmapped velocity pipeline == per-pair loop."""
        shape, tile = (20, 18, 16), (5, 5, 5)
        pairs = [make_pair(shape, tile=tile, magnitude=2.0, seed=s)
                 for s in (0, 1)]
        F = jnp.stack([p[0] for p in pairs])
        M = jnp.stack([p[1] for p in pairs])
        opts = RegistrationOptions(tile=tile, levels=2, iters=4, lr=0.3,
                                   transform="velocity",
                                   regularizer=bending(1e-4), **CONCRETE)
        res = register_batch(F, M, options=opts)
        for b, (f, m, _) in enumerate(pairs):
            solo = ffd_register(f, m, options=opts)
            np.testing.assert_allclose(np.asarray(res.warped[b]),
                                       np.asarray(solo.warped), atol=2e-5)

    def test_velocity_stop_parity(self):
        """The early-stopped while_loop path runs the velocity objective."""
        shape, tile = (20, 18, 16), (5, 5, 5)
        fixed, moving, _ = make_pair(shape, tile=tile, magnitude=2.0, seed=0)
        opts = RegistrationOptions(tile=tile, levels=2, iters=12, lr=0.3,
                                   transform="velocity",
                                   stop=ConvergenceConfig(tol=1e-3,
                                                          patience=2),
                                   **CONCRETE)
        res = ffd_register(fixed, moving, options=opts)
        assert res.steps is not None and len(res.steps) == 2
        assert all(1 <= s <= 12 for s in res.steps)
        assert np.isfinite(res.losses).all()
        # the full-budget run shares the objective: same loss at same step
        full = ffd_register(fixed, moving, options=opts.replace(stop=None))
        assert res.losses[-1] <= full.losses[-1] * 1.5 + 1e-6

    def test_velocity_mesh_parity(self):
        """The mesh-sharded batch == the single-device batch for velocity."""
        from repro.engine.shard import make_registration_mesh

        shape, tile = (20, 18, 16), (5, 5, 5)
        n = min(len(jax.devices()), 4)
        pairs = [make_pair(shape, tile=tile, magnitude=2.0, seed=s)
                 for s in range(max(n, 2) + 1)]  # non-divisible: pad path
        F = jnp.stack([p[0] for p in pairs])
        M = jnp.stack([p[1] for p in pairs])
        opts = RegistrationOptions(tile=tile, levels=2, iters=4, lr=0.3,
                                   transform="velocity", **CONCRETE)
        base = register_batch(F, M, options=opts)
        sharded = register_batch(F, M, options=opts,
                                 mesh=make_registration_mesh(n))
        np.testing.assert_allclose(np.asarray(sharded.warped),
                                   np.asarray(base.warped), atol=2e-5)
        np.testing.assert_allclose(np.asarray(sharded.losses),
                                   np.asarray(base.losses), rtol=2e-5)

    def test_fused_on_velocity_raises(self):
        with pytest.raises(ValueError, match="fused='on' is incompatible"):
            RegistrationOptions(fused="on", transform="velocity")
        f = jnp.zeros((16, 16, 16), jnp.float32)
        with pytest.raises(ValueError, match="fused='on' cannot run"):
            ffd_level_loss(f, f, tile=(5, 5, 5), bending_weight=0.0,
                           mode="separable", impl="jnp",
                           transform="velocity", fused="on")

    def test_fused_auto_velocity_resolves_off(self):
        from repro.engine.autotune import resolve_options

        opts = RegistrationOptions(tile=(5, 5, 5), transform="velocity",
                                   mode="separable", impl="jnp",
                                   grad_impl="xla", fused="auto")
        resolved = resolve_options(opts, (20, 18, 16))
        assert resolved.fused == "off"

    def test_velocity_options_cache_key_distinct(self):
        a = RegistrationOptions(transform="velocity")
        b = RegistrationOptions(transform=velocity(squarings=3))
        c = RegistrationOptions()
        assert a != b and a != c and hash(a) != hash(c)
        assert a == RegistrationOptions(transform=velocity())
