"""Distributed-runtime substrate tests: checkpoint/restart, resharding,
compression, data pipeline determinism, straggler tracking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.optim.compression import (
    compressed_psum, dequantize_int8, make_compressor, quantize_int8,
)
from repro.optim.optimizer import OptConfig
from repro.training.steps import init_train_state, make_train_step


def _tiny_state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    state = _tiny_state()
    ck.save(10, state)
    restored, step, extra = ck.restore(state)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_atomic_and_keep_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    state = _tiny_state()
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert sorted(ck.all_steps()) == [3, 4]
    # corrupt detection
    latest = tmp_path / "step_000000004" / "arrays.npz"
    latest.write_bytes(latest.read_bytes()[:-10] + b"0123456789")
    with pytest.raises(IOError):
        ck.restore(state, step=4)
    # older checkpoint still fine
    ck.restore(state, step=3)


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tiny_state(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_checkpoint_restores_across_shardings(tmp_path):
    """Elastic restart: save unsharded, restore onto a different layout."""
    from jax.sharding import NamedSharding, PartitionSpec
    ck = Checkpointer(tmp_path)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, PartitionSpec("data", None))}
    restored, _, _ = ck.restore(state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_to_unbiased():
    """EF compression: the *sum* over steps converges to the true sum."""
    comp = make_compressor()
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal((32, 32)) * 0.01, jnp.float32)
    ef = None
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        g_out, ef = comp({"g": g_true}, ef)
        acc = acc + g_out["g"]
    target = 50 * g_true
    rel = float(jnp.linalg.norm(acc - target) / jnp.linalg.norm(target))
    assert rel < 0.02, rel


def test_compressed_psum_matches_mean_scale():
    # single device: psum over a trivial axis still exercises the path
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS
    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)), jnp.float32)

    def f(x):
        return compressed_psum(x, "d")

    y = shard_map(f, mesh=mesh, in_specs=PS(), out_specs=PS())(x)
    assert float(jnp.max(jnp.abs(y - x))) < float(jnp.max(jnp.abs(x))) / 100


def test_pipeline_deterministic_and_sharded():
    base = dict(vocab_size=512, seq_len=64, global_batch=8, seed=3)
    p1 = TokenPipeline(PipelineConfig(**base))
    p2 = TokenPipeline(PipelineConfig(**base))
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # host sharding: different hosts, different data; same shapes
    h0 = TokenPipeline(PipelineConfig(**base, host_id=0, num_hosts=2))
    h1 = TokenPipeline(PipelineConfig(**base, host_id=1, num_hosts=2))
    a, b = h0.batch_at(0), h1.batch_at(0)
    assert a["tokens"].shape == (4, 64)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_train_resume_bitwise(tmp_path):
    """Kill-and-restart produces the same state as uninterrupted training."""
    from repro.launch.train import TrainLoop
    cfg = get_config("internlm2-1.8b", smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    ocfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=8)
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))

    # uninterrupted: 6 steps
    loop_a = TrainLoop(cfg, ocfg, tmp_path / "a")
    loop_a.init_or_restore()
    loop_a.run(pipe, 6, ckpt_every=100, log_every=100)

    # interrupted: 3 steps, new process-equivalent, 3 more
    loop_b = TrainLoop(cfg, ocfg, tmp_path / "b")
    loop_b.init_or_restore()
    loop_b.run(pipe, 3, ckpt_every=100, log_every=100)
    loop_b2 = TrainLoop(cfg, ocfg, tmp_path / "b")
    start = loop_b2.init_or_restore()
    assert start == 3
    loop_b2.run(pipe, 6, ckpt_every=100, log_every=100)

    wa = loop_a.state["params"]["blocks"]["attn"]["wq"]
    wb = loop_b2.state["params"]["blocks"]["attn"]["wq"]
    np.testing.assert_allclose(np.asarray(wa), np.asarray(wb), atol=1e-6)


def test_grad_accum_matches_full_batch():
    cfg = get_config("internlm2-1.8b", smoke=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    state = init_train_state(cfg, ocfg, seed=0)
    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    s1, m1 = jax.jit(make_train_step(cfg, ocfg))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, ocfg, grad_accum=4))(state, batch)
    w1 = s1["params"]["blocks"]["attn"]["wq"]
    w2 = s2["params"]["blocks"]["attn"]["wq"]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               atol=5e-5, rtol=1e-4)


def test_straggler_watchdog():
    from repro.launch.train import TrainLoop
    cfg = get_config("internlm2-1.8b", smoke=True)
    loop = TrainLoop(cfg, OptConfig(), "/tmp/unused_watchdog",
                     straggler_factor=2.0)
    for dt in [0.1] * 10 + [0.5] + [0.1] * 5 + [1.0]:
        loop._track_time(dt)
    assert loop.stragglers == 2
