"""repro.engine: scan-compiled loops, batched registration, BSI autotuner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ffd, metrics
from repro.core.registration import ffd_register
from repro.data.volumes import make_pair
from repro.engine import (adam_scan, autotune_bsi, register_batch,
                          resolve_bsi)

TILE = (6, 6, 6)


def _seed_adam_update(g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    """The seed's Python-loop Adam update, verbatim."""
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**step)
    vh = v / (1 - b2**step)
    return lr * mh / (jnp.sqrt(vh) + eps), m, v


def test_adam_scan_matches_python_loop_quadratic():
    def loss_fn(p):
        return jnp.sum((p - 3.0) ** 2)

    p0 = jnp.zeros((4,), jnp.float32)
    p_scan, trace = adam_scan(loss_fn, p0, iters=25, lr=0.1)

    p, m, v = p0, jnp.zeros_like(p0), jnp.zeros_like(p0)
    for i in range(1, 26):
        g = jax.grad(loss_fn)(p)
        upd, m, v = _seed_adam_update(g, m, v, i, 0.1)
        p = p - upd
    # scan computes the bias correction in f32 on-device; the python loop
    # computed b1**step in f64 — agreement to 1e-4 (the engine's contract)
    np.testing.assert_allclose(np.asarray(p_scan), np.asarray(p), atol=1e-4)
    assert trace.shape == (25,)
    assert abs(float(trace[-1]) - float(loss_fn(p))) < 1e-4
    # the trace is a descent trace on a convex objective
    assert float(trace[-1]) < float(trace[0])


def test_scan_ffd_register_matches_seed_python_loop():
    """The scan-based level loop reproduces the seed's per-step-jit loop."""
    fixed, moving, _ = make_pair(shape=(24, 20, 18), tile=TILE,
                                 magnitude=1.5, seed=3)
    iters, lr, bw = 8, 0.5, 5e-3
    gshape = ffd.grid_shape_for_volume(fixed.shape, TILE)

    def loss_fn(p):
        disp = ffd.dense_field(p, TILE, fixed.shape, mode="separable",
                               impl="jnp")
        warped = ffd.warp_volume(moving, disp)
        return metrics.ssd(warped, fixed) + bw * ffd.bending_energy(p)

    @jax.jit
    def step_fn(p, mm, vv, i):
        g = jax.grad(loss_fn)(p)
        upd, mm, vv = _seed_adam_update(g, mm, vv, i, lr)
        return p - upd, mm, vv

    phi = jnp.zeros(gshape + (3,), jnp.float32)
    mm, vv = jnp.zeros_like(phi), jnp.zeros_like(phi)
    for i in range(1, iters + 1):
        phi, mm, vv = step_fn(phi, mm, vv, i)

    res = ffd_register(fixed, moving, tile=TILE, levels=1, iters=iters,
                       lr=lr, bending_weight=bw, mode="separable",
                       impl="jnp")
    np.testing.assert_allclose(np.asarray(res.params), np.asarray(phi),
                               atol=1e-4)
    assert abs(res.losses[0] - float(loss_fn(phi))) < 1e-6


def test_register_batch_matches_per_pair():
    """A batch of 2 pairs in ONE jitted program == per-pair ffd_register."""
    pairs = [make_pair(shape=(24, 20, 18), tile=TILE, magnitude=1.5, seed=s)
             for s in (0, 1)]
    fixed = jnp.stack([p[0] for p in pairs])
    moving = jnp.stack([p[1] for p in pairs])
    kw = dict(tile=TILE, levels=2, iters=6, lr=0.5, bending_weight=5e-3,
              mode="separable", impl="jnp")

    batch = register_batch(fixed, moving, **kw)
    assert batch.warped.shape == fixed.shape
    assert batch.losses.shape == (2, 2)  # (batch, levels)

    for b, (f, m, _) in enumerate(pairs):
        single = ffd_register(f, m, **kw)
        np.testing.assert_allclose(np.asarray(batch.warped[b]),
                                   np.asarray(single.warped), atol=1e-4)
        np.testing.assert_allclose(np.asarray(batch.losses[b]),
                                   np.asarray(single.losses),
                                   rtol=1e-4, atol=1e-6)
        # registration actually did something on each pair
        assert float(metrics.ssim(batch.warped[b], f)) > \
            float(metrics.ssim(m, f))


def test_register_batch_rejects_bad_shapes():
    v = jnp.zeros((8, 8, 8), jnp.float32)
    with pytest.raises(ValueError):
        register_batch(v, v)  # missing batch axis
    with pytest.raises(ValueError):
        register_batch(jnp.zeros((2, 8, 8, 8)), jnp.zeros((3, 8, 8, 8)))


def test_autotune_returns_valid_choice_and_caches(tmp_path):
    cache = tmp_path / "bsi_autotune.json"
    choice = autotune_bsi((8, 8, 8), (3, 3, 3), 3, reps=1,
                          cache_path=str(cache))
    assert choice.mode in {"gather", "tt", "ttli", "separable", "matmul"}
    assert choice.impl in {"jnp", "pallas"}
    assert choice.us_per_call > 0
    assert cache.exists()
    # second call is served from cache (same result, no re-measurement)
    again = autotune_bsi((8, 8, 8), (3, 3, 3), 3, reps=1,
                         cache_path=str(cache))
    assert again == choice
    # a different cache file is tuned+written, not shadowed by the mem cache
    other = tmp_path / "other.json"
    autotune_bsi((8, 8, 8), (3, 3, 3), 3, reps=1, cache_path=str(other))
    assert other.exists()


def test_autotune_measure_grad_excludes_nondifferentiable(tmp_path):
    """With measure_grad, Pallas candidates (no VJP) drop out; a jnp form
    wins — the workload the registration loop actually runs."""
    choice = autotune_bsi(
        (7, 7, 7), (2, 2, 2), 2, reps=1, measure_grad=True,
        candidates=(("ttli", "pallas"), ("ttli", "jnp")),
        cache_path=str(tmp_path / "c.json"))
    assert (choice.mode, choice.impl) == ("ttli", "jnp")


def test_resolve_bsi_passthrough_and_partial_auto(tmp_path):
    # fully explicit choices never touch the tuner
    assert resolve_bsi("tt", "jnp", (8, 8, 8), (3, 3, 3)) == ("tt", "jnp")
    # fixing one axis narrows the candidates
    mode, impl = resolve_bsi("separable", "auto", (8, 8, 8), (3, 3, 3),
                             reps=1, cache_path=str(tmp_path / "c.json"))
    assert mode == "separable"
    assert impl in {"jnp", "pallas"}
    # an explicit impl overrides the backend default exclusion: asking for
    # pallas on CPU tunes the interpret-mode kernels rather than erroring
    mode, impl = resolve_bsi("auto", "pallas", (7, 7, 7), (2, 2, 2),
                             channels=2, reps=1,
                             cache_path=str(tmp_path / "p.json"))
    assert impl == "pallas"
    assert mode in {"tt", "ttli", "separable", "matmul"}
    # no candidate matches an unknown mode
    with pytest.raises(ValueError):
        resolve_bsi("nosuch", "auto", (8, 8, 8), (3, 3, 3))
