"""Property-based tests (hypothesis) for the system's mathematical invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="needs the 'dev' extra: pip install -e '.[dev]'")
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.bspline import bspline_basis
from repro.core.interpolate import MODES
from repro.kernels.ref import bsi_ref

COMMON = dict(deadline=None, max_examples=20,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(u=st.floats(0.0, 1.0, allow_nan=False))
@settings(**COMMON)
def test_basis_partition_of_unity_pointwise(u):
    b = np.asarray(bspline_basis(jnp.float32(u)))
    assert abs(b.sum() - 1.0) < 1e-6
    assert (b >= -1e-7).all()


@given(
    tiles=st.tuples(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4)),
    d=st.integers(2, 6),
    mode=st.sampled_from(sorted(MODES)),
    seed=st.integers(0, 2**16),
)
@settings(**COMMON)
def test_all_modes_agree_with_oracle(tiles, d, mode, seed):
    rng = np.random.default_rng(seed)
    grid = tuple(t + 3 for t in tiles)
    phi = jnp.asarray(rng.standard_normal(grid + (2,)), jnp.float32)
    ref = np.asarray(bsi_ref(phi, (d, d, d)))
    out = np.asarray(MODES[mode](phi, (d, d, d)))
    np.testing.assert_allclose(out, ref, atol=5e-5)


@given(c=st.floats(-5.0, 5.0, allow_nan=False), d=st.integers(2, 7))
@settings(**COMMON)
def test_constant_reproduction(c, d):
    """Partition of unity => a constant grid interpolates to the constant."""
    phi = jnp.full((5, 5, 5, 1), c, jnp.float32)
    out = np.asarray(bsi_ref(phi, (d, d, d)))
    np.testing.assert_allclose(out, c, atol=1e-4)


@given(
    a=st.floats(-2.0, 2.0, allow_nan=False),
    b=st.floats(-2.0, 2.0, allow_nan=False),
    seed=st.integers(0, 2**16),
)
@settings(**COMMON)
def test_linearity(a, b, seed):
    """BSI is linear in the control grid: T(a*p + b*q) = a*T(p) + b*T(q)."""
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.standard_normal((6, 5, 5, 2)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((6, 5, 5, 2)), jnp.float32)
    t = (4, 4, 4)
    lhs = np.asarray(bsi_ref(a * p + b * q, t))
    rhs = a * np.asarray(bsi_ref(p, t)) + b * np.asarray(bsi_ref(q, t))
    np.testing.assert_allclose(lhs, rhs, atol=1e-4)


@given(seed=st.integers(0, 2**16))
@settings(**COMMON)
def test_locality(seed):
    """Perturbing one control point only affects its 4-tile neighbourhood."""
    rng = np.random.default_rng(seed)
    phi = jnp.asarray(rng.standard_normal((8, 8, 8, 1)), jnp.float32)
    d = 4
    base = np.asarray(bsi_ref(phi, (d, d, d)))
    # bump stored point (4, 4, 4) -> affects tiles 1..4 per axis only
    phi2 = phi.at[4, 4, 4, 0].add(10.0)
    bumped = np.asarray(bsi_ref(phi2, (d, d, d)))
    diff = np.abs(bumped - base)[..., 0]
    affected = diff > 1e-5
    xs, ys, zs = np.where(affected)
    # stored index 4 = paper control index 3: support = tiles t with
    # t <= 4 <= t+3  =>  tiles 1..4  => voxels [d, 5d)
    for coords in (xs, ys, zs):
        assert coords.min() >= d
        assert coords.max() < 5 * d


@given(
    tiles=st.tuples(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4)),
    d=st.integers(2, 6),
    grad_impl=st.sampled_from(["jnp", "pallas"]),
    seed=st.integers(0, 2**16),
)
@settings(**COMMON)
def test_adjoint_dot_product_identity(tiles, d, grad_impl, seed):
    """Transpose correctness: <S p, g> == <p, S^T g> for the analytic
    adjoint of the BSI linear map S (both implementations)."""
    from repro.core.interpolate import bsi_adjoint

    rng = np.random.default_rng(seed)
    grid = tuple(t + 3 for t in tiles)
    dense = tuple(t * d for t in tiles)
    p = jnp.asarray(rng.standard_normal(grid + (2,)), jnp.float32)
    g = jnp.asarray(rng.standard_normal(dense + (2,)), jnp.float32)
    sp = bsi_ref(p, (d, d, d))
    lhs = float(jnp.vdot(sp, g))
    rhs = float(jnp.vdot(p, bsi_adjoint(g, (d, d, d), impl=grad_impl)))
    # normalise by the Cauchy-Schwarz scale of the inner product, not by the
    # (possibly near-cancelling) value itself — f32 accumulation error grows
    # with the number of summed terms, the dot value does not
    scale = max(1.0, float(jnp.linalg.norm(sp)) * float(jnp.linalg.norm(g)))
    assert abs(lhs - rhs) / scale < 1e-5


@given(seed=st.integers(0, 2**16), d=st.integers(2, 5))
@settings(**COMMON)
def test_translation_equivariance(seed, d):
    """Shifting the control grid by one point shifts the field by one tile."""
    rng = np.random.default_rng(seed)
    phi = jnp.asarray(rng.standard_normal((8, 6, 6, 1)), jnp.float32)
    t = (d, d, d)
    full = np.asarray(bsi_ref(phi, t))
    shifted = np.asarray(bsi_ref(phi[1:], t))
    np.testing.assert_allclose(full[d:], shifted[: full.shape[0] - d], atol=1e-5)


@given(seed=st.integers(0, 2**16))
@settings(deadline=None, max_examples=10)
def test_quantize_int8_bounded_error(seed):
    from repro.optim.compression import dequantize_int8, quantize_int8

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((32,)) * rng.uniform(0.01, 100),
                    jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


@given(
    batch=st.integers(1, 4), heads=st.integers(1, 4),
    seq=st.integers(4, 24), seed=st.integers(0, 2**16),
)
@settings(deadline=None, max_examples=10)
def test_blockwise_attention_matches_full(batch, heads, seq, seed):
    from repro.models.attention import attend_blockwise, attend_full

    rng = np.random.default_rng(seed)
    hd = 8
    q = jnp.asarray(rng.standard_normal((batch, seq, heads, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((batch, seq, heads, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((batch, seq, heads, hd)), jnp.float32)
    pos = jnp.arange(seq)
    full = np.asarray(attend_full(q, k, v, q_positions=pos, k_positions=pos))
    # chunk sizes that divide seq exercise the scan path
    for c in {1, 2, 4}:
        if seq % c:
            continue
        blk = np.asarray(attend_blockwise(
            q, k, v, q_positions=pos, k_positions=pos, q_chunk=c, kv_chunk=c))
        np.testing.assert_allclose(blk, full, atol=2e-5)
