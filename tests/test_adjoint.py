"""The gather-based BSI adjoint: custom VJP vs autodiff, kernels, engine.

The contract (ISSUE 4): every ``grad_impl`` computes the gradient of the
same linear map, so the analytic adjoint must match ``jax.grad`` of the
``bsi_gather`` reference to 1e-5 across modes/tiles/channels, the Pallas
adjoint must match the jnp separable-transpose, and registration driven
through any ``grad_impl`` must land on the same result to 1e-4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.interpolate import (GRAD_IMPLS, bsi_adjoint,
                                    bsi_adjoint_separable, bsi_gather,
                                    interpolate)
from repro.data.volumes import make_pair
from repro.kernels import ops

SHAPE_SWEEP = [
    # (grid points per axis, tile, channels)
    ((7, 6, 5), (5, 4, 3), 3),
    ((9, 9, 9), (5, 5, 5), 3),     # paper's default tile
    ((4, 4, 4), (3, 3, 3), 1),     # single tile per axis, smallest tile
    ((11, 4, 6), (7, 7, 7), 2),    # paper's largest tile, non-cubic grid
    ((5, 13, 9), (4, 6, 5), 3),    # mixed tile
]


def _cotangent(grid, tile, c, seed=0):
    rng = np.random.default_rng(seed)
    dense = tuple((g - 3) * t for g, t in zip(grid, tile))
    return jnp.asarray(rng.standard_normal(dense + (c,)), jnp.float32)


def _grad_of_gather_ref(phi, tile, g):
    return jax.grad(lambda p: jnp.vdot(bsi_gather(p, tile), g))(phi)


@pytest.mark.parametrize("grid,tile,c", SHAPE_SWEEP)
def test_adjoint_matches_grad_of_gather_reference(grid, tile, c):
    rng = np.random.default_rng(hash((grid, tile)) % 2**31)
    phi = jnp.asarray(rng.standard_normal(grid + (c,)), jnp.float32)
    g = _cotangent(grid, tile, c)
    ref = _grad_of_gather_ref(phi, tile, g)
    for impl in ("jnp", "pallas"):
        out = bsi_adjoint(g, tile, impl=impl)
        assert out.shape == ref.shape
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


@pytest.mark.parametrize("mode", ["gather", "tt", "ttli", "separable"])
@pytest.mark.parametrize("grad_impl", ["jnp", "pallas"])
def test_custom_vjp_matches_autodiff_across_modes(mode, grad_impl):
    grid, tile, c = (8, 7, 6), (4, 3, 5), 3
    rng = np.random.default_rng(5)
    phi = jnp.asarray(rng.standard_normal(grid + (c,)), jnp.float32)
    g = _cotangent(grid, tile, c, seed=5)
    ref = _grad_of_gather_ref(phi, tile, g)
    got = jax.grad(
        lambda p: jnp.vdot(interpolate(p, tile, mode=mode,
                                       grad_impl=grad_impl), g))(phi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_pallas_forward_differentiable_with_custom_adjoint():
    """The Pallas forward kernels have no VJP under plain autodiff; the
    custom adjoint is what makes them usable inside the optimisation loop."""
    grid, tile = (7, 7, 7), (4, 4, 4)
    rng = np.random.default_rng(2)
    phi = jnp.asarray(rng.standard_normal(grid + (3,)), jnp.float32)
    g = _cotangent(grid, tile, 3, seed=2)
    ref = _grad_of_gather_ref(phi, tile, g)
    got = jax.grad(
        lambda p: jnp.vdot(interpolate(p, tile, mode="ttli", impl="pallas",
                                       grad_impl="jnp"), g))(phi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    with pytest.raises(Exception):
        jax.grad(lambda p: interpolate(p, tile, mode="ttli", impl="pallas",
                                       grad_impl="xla").sum())(phi)


def test_adjoint_pallas_block_shapes_and_chunking(monkeypatch):
    g = _cotangent((9, 9, 15), (4, 4, 3), 3, seed=7)
    ref = bsi_adjoint_separable(g, (4, 4, 3))
    for bc in [(1, 1, 1), (2, 2, 2), (4, 2, 1)]:
        out = ops.bsi_adjoint_pallas(g, (4, 4, 3), block_ctrl=bc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
    # a tiny budget forces the z-chunked dispatch (several pallas_calls whose
    # slabs overlap by the 3-tile halo) — answers must not change.  The
    # post-patch call uses a block_ctrl no earlier call traced with: jit
    # caches per static-arg signature, so reusing one would silently serve
    # the unchunked program traced under the default budget.
    monkeypatch.setattr(ops, "_VMEM_BUDGET_BYTES", 2 * 2**20)
    picked = {}
    real_pick = ops._pick_z_chunk

    def spy(gp_shape, nz_pad, bz, itemsize, **kw):
        picked["chunk"] = real_pick(gp_shape, nz_pad, bz, itemsize, **kw)
        picked["nz_pad"] = nz_pad
        return picked["chunk"]

    monkeypatch.setattr(ops, "_pick_z_chunk", spy)
    out = ops.bsi_adjoint_pallas(g, (4, 4, 3), block_ctrl=(2, 1, 2))
    assert picked["chunk"] < picked["nz_pad"], picked  # really chunked
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_adjoint_accumulates_fp32_for_bf16_cotangents():
    g = _cotangent((8, 8, 8), (4, 4, 4), 3)
    for impl in ("jnp", "pallas"):
        out = bsi_adjoint(g.astype(jnp.bfloat16), (4, 4, 4), impl=impl)
        assert out.dtype == jnp.float32
        ref = bsi_adjoint(g, (4, 4, 4), impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-2)


def test_interpolate_rejects_unknown_grad_impl():
    phi = jnp.zeros((5, 5, 5, 3), jnp.float32)
    with pytest.raises(ValueError):
        interpolate(phi, (3, 3, 3), grad_impl="nosuch")
    assert set(GRAD_IMPLS) == {"xla", "jnp", "pallas", "matmul"}


def test_custom_vjp_linear_no_residuals():
    """BSI is linear: the VJP is independent of the primal point (the fwd
    rule saves no residuals), so pulling back the same cotangent at two
    different grids gives bit-identical gradients."""
    from repro.core.interpolate import _custom_vjp_interp

    f = _custom_vjp_interp((4, 4, 4), "separable", "jnp", "jnp", None,
                           "float32")
    rng = np.random.default_rng(0)
    p1 = jnp.asarray(rng.standard_normal((7, 7, 7, 3)), jnp.float32)
    p2 = jnp.asarray(rng.standard_normal((7, 7, 7, 3)), jnp.float32)
    g = _cotangent((7, 7, 7), (4, 4, 4), 3)
    _, vjp1 = jax.vjp(f, p1)
    _, vjp2 = jax.vjp(f, p2)
    np.testing.assert_array_equal(np.asarray(vjp1(g)[0]),
                                  np.asarray(vjp2(g)[0]))


def test_bf16_warp_coordinates_stay_fp32_beyond_256_voxels():
    """bf16 cannot represent integers above 256: a bf16 identity grid would
    shift sampling by whole voxels on paper-scale volumes.  warp_volume must
    keep coordinates fp32 and cast only the sampled intensities."""
    from repro.core import ffd

    # alternating 0/1 intensities are bf16-exact, so any error is a
    # *coordinate* error: a one-voxel shift flips the parity to 1.0
    x = jnp.arange(320, dtype=jnp.float32)
    vol = jnp.broadcast_to((x % 2)[:, None, None], (320, 2, 2))
    disp = jnp.zeros(vol.shape + (3,), jnp.float32).at[..., 0].set(1.0)
    warped = ffd.warp_volume(vol, disp, compute_dtype="bfloat16")
    err = jnp.abs(warped[:-1].astype(jnp.float32) - vol[1:])
    # the old bug (bf16 identity grid): indices in [256, 320) quantise to
    # even, the integer shift lands on the wrong voxel, err.max() == 1.0
    assert float(err.max()) < 1e-2, float(err.max())


def test_bf16_compute_registration_converges_close_to_fp32():
    """Mixed-precision first step (ROADMAP): bf16 BSI + warp inside the
    loop, fp32 params/adjoint accumulation, on the bench small preset."""
    fixed, moving, _ = make_pair(shape=(24, 20, 18), tile=(6, 6, 6),
                                 magnitude=1.5, seed=3)
    from repro.core.registration import ffd_register

    kw = dict(tile=(6, 6, 6), levels=2, iters=8, mode="separable",
              impl="jnp", grad_impl="jnp")
    r32 = ffd_register(fixed, moving, **kw)
    r16 = ffd_register(fixed, moving, compute_dtype="bfloat16", **kw)
    assert r16.warped.dtype == r32.warped.dtype
    # both descend to comparable objectives ...
    assert r16.losses[-1] < 1.1 * r32.losses[-1] + 1e-4
    # ... and land on nearby warps (bf16 has ~3 decimal digits)
    mae = float(jnp.abs(r16.warped - r32.warped).mean())
    assert mae < 5e-3, mae


def test_register_batch_grad_impl_variants_agree():
    """Regression: the batched engine lands on the same registration for
    every adjoint implementation (1e-4, the engine's parity contract)."""
    from repro.engine import register_batch

    pairs = [make_pair(shape=(20, 18, 16), tile=(5, 5, 5), magnitude=1.2,
                       seed=s) for s in (0, 1)]
    F = jnp.stack([p[0] for p in pairs])
    M = jnp.stack([p[1] for p in pairs])
    kw = dict(tile=(5, 5, 5), levels=2, iters=5, mode="separable",
              impl="jnp")
    base = register_batch(F, M, grad_impl="xla", **kw)
    for gi in ("jnp", "pallas"):
        res = register_batch(F, M, grad_impl=gi, **kw)
        np.testing.assert_allclose(np.asarray(res.warped),
                                   np.asarray(base.warped), atol=1e-4)
        np.testing.assert_allclose(np.asarray(res.params),
                                   np.asarray(base.params), atol=1e-4)
        np.testing.assert_allclose(np.asarray(res.losses),
                                   np.asarray(base.losses),
                                   rtol=1e-4, atol=1e-6)


def test_sharded_register_batch_with_custom_adjoint_matches_unsharded():
    """Acceptance: sharded results unchanged (1e-4) under the custom VJP."""
    from repro.engine import make_registration_mesh, register_batch

    pairs = [make_pair(shape=(18, 16, 14), tile=(5, 5, 5), magnitude=1.2,
                       seed=s) for s in range(3)]
    F = jnp.stack([p[0] for p in pairs])
    M = jnp.stack([p[1] for p in pairs])
    kw = dict(tile=(5, 5, 5), levels=1, iters=4, mode="separable",
              impl="jnp", grad_impl="jnp")
    base = register_batch(F, M, **kw)
    res = register_batch(F, M, mesh=make_registration_mesh(), **kw)
    np.testing.assert_allclose(np.asarray(res.warped),
                               np.asarray(base.warped), atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.params),
                               np.asarray(base.params), atol=1e-4)


def test_autotune_enumerates_adjoint_axis(tmp_path):
    """resolve_bsi with grad_impl='auto' tunes the (fwd x adjoint) matrix
    and returns a concrete triple the runner caches key on."""
    from repro.engine import resolve_bsi

    mode, impl, gi = resolve_bsi(
        "separable", "jnp", (8, 8, 8), (3, 3, 3), grad_impl="auto",
        reps=1, cache_path=str(tmp_path / "c.json"))
    assert (mode, impl) == ("separable", "jnp")
    assert gi in GRAD_IMPLS
    # fully explicit triples never touch the tuner
    assert resolve_bsi("tt", "jnp", (8, 8, 8), (3, 3, 3),
                       grad_impl="jnp") == ("tt", "jnp", "jnp")
    # legacy pair behaviour is preserved for forward-only callers
    assert resolve_bsi("tt", "jnp", (8, 8, 8), (3, 3, 3)) == ("tt", "jnp")


def test_autotune_compute_dtype_keys_and_excludes_xla(tmp_path):
    """Under a reduced compute dtype, 'auto' never picks plain autodiff
    (its backward would accumulate in that dtype, not fp32), and the cache
    entry is per-dtype so fp32/bf16 callers never share a winner."""
    import json

    from repro.engine import resolve_bsi

    cache = str(tmp_path / "c.json")
    # a single-candidate pool short-circuits the tuner, so leave mode open
    # to force a measured choice (small grid keeps the sweep cheap)
    _, _, gi = resolve_bsi("auto", "jnp", (7, 7, 7), (2, 2, 2),
                           grad_impl="auto", reps=1, cache_path=cache,
                           compute_dtype="bfloat16")
    assert gi != "xla"
    resolve_bsi("auto", "jnp", (7, 7, 7), (2, 2, 2),
                grad_impl="auto", reps=1, cache_path=cache)
    keys = list(json.load(open(cache))["entries"])  # v2 schema wrapper
    assert any("|cd=bfloat16|" in k for k in keys)
    assert any("|cd=" not in k for k in keys)
    assert len(keys) == 2  # distinct entries, no sharing


def test_autotune_selects_custom_adjoint_for_scatter_heavy_forward(tmp_path):
    """Acceptance: for the gather forward (whose XLA transpose is the
    per-voxel scatter-add) the tuner measures the custom VJP as fastest and
    selects it — the margin is ~65x on the CI preset, far beyond timing
    noise."""
    from repro.engine.autotune import autotune_bsi

    choice = autotune_bsi(
        (8, 8, 8), (4, 4, 4), 3, reps=1, measure_grad=True,
        candidates=(("gather", "jnp"),), grad_impls=("xla", "jnp"),
        cache_path=str(tmp_path / "c.json"))
    assert choice.grad_impl == "jnp"


def test_autotune_pallas_forward_survives_with_custom_adjoint(tmp_path):
    """Under measure_grad, (pallas fwd, xla adjoint) is undifferentiable and
    drops out — but (pallas fwd, jnp adjoint) is a live candidate now."""
    from repro.engine.autotune import autotune_bsi

    choice = autotune_bsi(
        (7, 7, 7), (2, 2, 2), 2, reps=1, measure_grad=True,
        candidates=(("ttli", "pallas", "xla"), ("ttli", "pallas", "jnp")),
        cache_path=str(tmp_path / "c.json"))
    assert (choice.mode, choice.impl, choice.grad_impl) == \
        ("ttli", "pallas", "jnp")


def test_pick_block_ctrl_clamps_to_grid():
    bc = ops.pick_block_ctrl((2, 2, 1), (5, 5, 5), 3, 4)
    assert bc == (2, 2, 1)
    big = ops.pick_block_ctrl((64, 64, 64), (7, 7, 7), 3, 4, budget=2**20)
    win = (big[0] + 3) * 7 * (big[1] + 3) * 7 * (big[2] + 3) * 7 * 3 * 4
    assert 4 * win < 2**20 // 2 or max(big) == 1
