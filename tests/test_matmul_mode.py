"""Matrix-form BSI (mode="matmul"): parity with the other forms end to end.

The matmul mode evaluates every tile as one (d^3, 64) @ (64, C) basis
contraction (Wu & Zou's matrix representation) — ISSUE 9 acceptance: equal
to the separable form to 1e-5 in value and gradient, in jnp and Pallas, in
bf16 (fp32 accumulation), under vmap and on the 8-fake-device sharded job.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bspline import basis_matrix
from repro.core.interpolate import (bsi_adjoint_matmul, bsi_adjoint_separable,
                                    bsi_gather, bsi_matmul, bsi_separable,
                                    interpolate)
from repro.kernels import ops

# (grid points per axis, tile) — mixed tiles, plus shapes whose tile counts
# are NOT divisible by the kernels' default block picks (the pad-and-crop
# path)
SHAPE_SWEEP = [
    ((7, 6, 5), (5, 4, 3)),
    ((8, 8, 8), (5, 5, 5)),
    ((10, 5, 9), (3, 5, 2)),     # non-divisible tile counts: 7, 2, 6
    ((5, 13, 9), (4, 6, 5)),
]


def _phi(grid, c=3, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(grid + (c,)), jnp.float32)


def test_basis_matrix_shape_and_partition_of_unity():
    tile = (3, 4, 5)
    b = basis_matrix(tile, jnp.float32)
    assert b.shape == (3 * 4 * 5, 64)
    # each voxel's 64 weights are a triple partition of unity
    np.testing.assert_allclose(np.asarray(jnp.sum(b, axis=1)), 1.0,
                               atol=1e-6)


@pytest.mark.parametrize("grid,tile", SHAPE_SWEEP)
def test_matmul_matches_separable_jnp(grid, tile):
    phi = _phi(grid, seed=hash((grid, tile)) % 2**31)
    a = bsi_separable(phi, tile)
    b = bsi_matmul(phi, tile)
    assert b.shape == a.shape
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-5)


@pytest.mark.parametrize("grid,tile", SHAPE_SWEEP)
def test_matmul_pallas_matches_jnp(grid, tile):
    phi = _phi(grid, seed=1)
    ref = bsi_matmul(phi, tile)
    out = ops.bsi_pallas(phi, tile, mode="matmul")
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_matmul_bf16_operands_fp32_accumulation():
    """bf16 operands stay bf16 (output dtype) but partial sums accumulate in
    fp32: the bf16 matmul result must sit within bf16 rounding of the fp32
    answer, not drift with the 64-term reduction length."""
    grid, tile = (8, 8, 8), (5, 5, 5)
    phi = _phi(grid, seed=2)
    ref = bsi_matmul(phi, tile)  # fp32
    for impl, fn in (("jnp", lambda: bsi_matmul(phi, tile, jnp.bfloat16)),
                     ("pallas", lambda: ops.bsi_pallas(
                         phi, tile, mode="matmul", dtype=jnp.bfloat16))):
        out = fn()
        assert out.dtype == jnp.bfloat16, impl
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=5e-2)


@pytest.mark.parametrize("grid,tile", SHAPE_SWEEP[:2])
def test_matmul_grad_matches_gather_adjoint(grid, tile):
    """Gradient parity vs autodiff of the gather baseline, for the jnp and
    Pallas forwards under both the matmul custom-VJP adjoint and autodiff."""
    phi = _phi(grid, seed=3)
    shape = tuple((g - 3) * t for g, t in zip(grid, tile)) + (3,)
    g = jnp.asarray(np.random.default_rng(4).standard_normal(shape),
                    jnp.float32)
    ref = jax.grad(lambda p: jnp.vdot(bsi_gather(p, tile), g))(phi)
    cases = [
        ("jnp/xla", dict(impl="jnp", grad_impl="xla")),
        ("jnp/matmul", dict(impl="jnp", grad_impl="matmul")),
        ("pallas/matmul", dict(impl="pallas", grad_impl="matmul")),
        ("pallas/jnp", dict(impl="pallas", grad_impl="jnp")),
    ]
    for label, kw in cases:
        got = jax.grad(lambda p: jnp.vdot(
            interpolate(p, tile, mode="matmul", **kw), g))(phi)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5, err_msg=label)


def test_matmul_adjoint_forms_agree():
    tile = (3, 4, 5)
    g = jnp.asarray(np.random.default_rng(5).standard_normal((12, 20, 15, 3)),
                    jnp.float32)
    a = bsi_adjoint_separable(g, tile)
    b = bsi_adjoint_matmul(g, tile)
    p = ops.bsi_adjoint_pallas(g, tile, form="matmul")
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-5)
    np.testing.assert_allclose(np.asarray(p), np.asarray(a), atol=1e-5)


def test_matmul_under_vmap():
    grid, tile = (7, 6, 5), (5, 4, 3)
    phis = jnp.stack([_phi(grid, seed=s) for s in range(3)])
    ref = jax.vmap(lambda p: bsi_separable(p, tile))(phis)
    out = jax.vmap(lambda p: interpolate(p, tile, mode="matmul",
                                         grad_impl="matmul"))(phis)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # ... and its gradient, batched
    g = jnp.ones_like(ref)
    gref = jax.vmap(lambda p, c: jax.grad(
        lambda q: jnp.vdot(bsi_gather(q, tile), c))(p))(phis, g)
    gout = jax.vmap(lambda p, c: jax.grad(lambda q: jnp.vdot(
        interpolate(q, tile, mode="matmul", grad_impl="matmul"), c))(p))(
            phis, g)
    np.testing.assert_allclose(np.asarray(gout), np.asarray(gref), atol=1e-5)


def test_matmul_mode_reaches_registration_options():
    """mode="matmul" is a valid RegistrationOptions axis and registers a
    pair end-to-end (the options/cache-key plumbing inherits the mode)."""
    from repro.core.options import RegistrationOptions
    from repro.core.registration import ffd_register
    from repro.data.volumes import make_pair

    f, m, _ = make_pair(shape=(18, 16, 14), tile=(5, 5, 5), magnitude=1.0,
                        seed=0)
    common = dict(tile=(5, 5, 5), levels=1, iters=3, fused="off")
    res = ffd_register(f, m, options=RegistrationOptions(
        mode="matmul", impl="jnp", grad_impl="matmul", **common))
    base = ffd_register(f, m, options=RegistrationOptions(
        mode="separable", impl="jnp", grad_impl="jnp", **common))
    np.testing.assert_allclose(np.asarray(res.losses),
                               np.asarray(base.losses), rtol=1e-4, atol=1e-6)


def test_matmul_sharded_8dev_subprocess():
    """The 8-fake-device sharded batch runs mode="matmul" and matches the
    unsharded result (fresh process so the device count holds regardless of
    the parent's backend)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import jax, jax.numpy as jnp, numpy as np
        from repro.data.volumes import make_pair
        from repro.engine import register_batch, make_registration_mesh
        assert jax.device_count() == 8, jax.devices()
        pairs = [make_pair(shape=(18, 16, 14), tile=(5, 5, 5),
                           magnitude=1.2, seed=s) for s in range(3)]
        F = jnp.stack([p[0] for p in pairs])
        M = jnp.stack([p[1] for p in pairs])
        kw = dict(tile=(5, 5, 5), levels=2, iters=4,
                  mode="matmul", impl="jnp", grad_impl="matmul")
        base = register_batch(F, M, **kw)
        sep = register_batch(F, M, tile=(5, 5, 5), levels=2, iters=4,
                             mode="separable", impl="jnp", grad_impl="jnp")
        np.testing.assert_allclose(np.asarray(base.losses),
                                   np.asarray(sep.losses),
                                   rtol=1e-4, atol=1e-6)
        mesh = make_registration_mesh()
        res = register_batch(F, M, mesh=mesh, **kw)
        np.testing.assert_allclose(np.asarray(res.warped),
                                   np.asarray(base.warped), atol=1e-4)
        np.testing.assert_allclose(np.asarray(res.params),
                                   np.asarray(base.params), atol=1e-4)
        print("MATMUL_SHARD_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)  # the child pins its own before jax imports
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "MATMUL_SHARD_OK" in r.stdout, r.stderr[-2000:]
