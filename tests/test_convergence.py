"""Convergence subsystem (ISSUE 5): early-stopped Adam + per-pair masking.

Covers the tentpole (``engine.convergence``: ``ConvergenceConfig`` /
``adam_until``, ``stop=`` through ``register_batch`` / ``ffd_register`` /
the sharded pipeline) and the satellite bugfixes that ride along
(``adam_scan`` trace restructure, ``pad_batch`` B=0, fp32 objective
scoring, ``BatchRegistrationResult.compiled``, the autotuner's fixed-iters
pin).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ffd
from repro.core.registration import ffd_register
from repro.data.volumes import make_pair
from repro.engine import (ConvergenceConfig, adam_scan, adam_until,
                          autotune_bsi, make_registration_mesh,
                          register_batch)
from repro.engine.batch import ffd_level_loss
from repro.engine.shard import pad_batch

TILE = (6, 6, 6)
SHAPE = (22, 20, 18)
# the bench small early-stop preset's knobs (registration_bench
# --earlystop): monotone descent at this lr, so the plateau rule is clean
KW = dict(tile=TILE, levels=2, iters=24, lr=0.1, mode="separable",
          impl="jnp")
STOP = ConvergenceConfig(tol=3e-4, patience=8)


def _stack(mags):
    pairs = [make_pair(shape=SHAPE, tile=TILE, magnitude=m, seed=s)
             for s, m in enumerate(mags)]
    return (jnp.stack([p[0] for p in pairs]),
            jnp.stack([p[1] for p in pairs]))


# ---------------------------------------------------------------- config

def test_convergence_config_validates_and_resolves():
    with pytest.raises(ValueError):
        ConvergenceConfig(tol=-1.0)
    with pytest.raises(ValueError):
        ConvergenceConfig(patience=0)
    with pytest.raises(ValueError):
        ConvergenceConfig(max_iters=0)
    cfg = ConvergenceConfig(tol=1e-3, patience=4).resolve(40)
    assert cfg.max_iters == 40  # inherits the caller's iters
    assert ConvergenceConfig(max_iters=7).resolve(40).max_iters == 7
    assert hash(cfg)  # lru_cache key material
    with pytest.raises(ValueError):  # unresolved config is rejected
        adam_until(lambda p: jnp.sum(p * p), jnp.zeros(3),
                   stop=ConvergenceConfig(), lr=0.1)


# ------------------------------------------------------- adam_until core

def test_adam_until_stops_early_and_pads_trace():
    """steps_taken < max_iters on an easy problem; the padded trace keeps
    the fixed-length shape and trace[-1] = loss of the returned params."""
    def loss_fn(p):
        return jnp.sum((p - 3.0) ** 2)

    p0 = jnp.zeros((4,), jnp.float32)
    stop = ConvergenceConfig(tol=1e-4, patience=3).resolve(200)
    p, trace, k = jax.jit(
        lambda q: adam_until(loss_fn, q, stop=stop, lr=0.5))(p0)
    assert trace.shape == (200,)
    assert int(k) < 200
    assert float(trace[-1]) == float(trace[int(k) - 1]) or \
        float(trace[-1]) <= float(trace[int(k) - 1])  # padded with best
    # the executed prefix is identical to the fixed-length scan
    p_fix, t_fix = adam_scan(loss_fn, p0, iters=int(k), lr=0.5)
    np.testing.assert_allclose(np.asarray(trace[:int(k)]),
                               np.asarray(t_fix), rtol=1e-6)


def test_adam_until_exhausted_budget_matches_adam_scan():
    """With a budget too small to plateau, the while loop == the scan."""
    def loss_fn(p):
        return jnp.sum((p - 3.0) ** 2)

    p0 = jnp.arange(4, dtype=jnp.float32)
    stop = ConvergenceConfig(tol=1e-6, patience=10).resolve(12)
    p_u, t_u, k = adam_until(loss_fn, p0, stop=stop, lr=0.1)
    p_s, t_s = adam_scan(loss_fn, p0, iters=12, lr=0.1)
    assert int(k) == 12
    np.testing.assert_allclose(np.asarray(t_u), np.asarray(t_s), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p_u), np.asarray(p_s), atol=1e-7)


def test_adam_until_returns_best_params_when_optimiser_degrades():
    """A pair the loop can only make worse keeps its (best) initial params
    — the pad_batch-filler / already-converged lane story."""
    def loss_fn(p):
        return jnp.sum(p * p)  # start at the optimum

    p0 = jnp.zeros((4,), jnp.float32)
    stop = ConvergenceConfig(tol=1e-4, patience=4).resolve(50)
    p, trace, k = adam_until(loss_fn, p0, stop=stop, lr=0.5)
    assert int(k) == 4  # stops as soon as the window closes
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p0))
    assert float(trace[-1]) == 0.0  # padded with the best (initial) loss


# ------------------------------------------- satellite: adam_scan re-jig

def _adam_scan_pre_issue5(loss_fn, params, *, iters, lr, b1=0.9, b2=0.999,
                          eps=1e-8):
    """The pre-ISSUE-5 implementation: eval-then-update steps plus one
    extra full forward pass (`loss_fn(p)[None]`) to close the trace."""
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)

    def step(carry, i):
        p, m, v = carry
        loss, g = jax.value_and_grad(loss_fn)(p)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**i)
        vh = v / (1 - b2**i)
        return (p - lr * mh / (jnp.sqrt(vh) + eps), m, v), loss

    steps = jnp.arange(1, iters + 1, dtype=jnp.float32)
    (p, _, _), pre = jax.lax.scan(step, (params, m, v), steps)
    return p, jnp.concatenate([pre[1:], loss_fn(p)[None]])


def test_adam_scan_trace_matches_old_closing_forward_impl():
    """Satellite: the restructured step (carrying the post-update loss)
    keeps the trace convention — equality vs the old implementation at
    1e-6 — without the separate trace-closing loss_fn call."""
    fixed, moving, _ = make_pair(shape=(18, 16, 14), tile=(5, 5, 5),
                                 magnitude=1.2, seed=0)
    loss_fn = ffd_level_loss(fixed, moving, tile=(5, 5, 5),
                             bending_weight=5e-3, mode="separable",
                             impl="jnp")
    gshape = ffd.grid_shape_for_volume(fixed.shape, (5, 5, 5))
    p0 = jnp.zeros(gshape + (3,), jnp.float32)
    p_old, t_old = _adam_scan_pre_issue5(loss_fn, p0, iters=6, lr=0.3)
    p_new, t_new = adam_scan(loss_fn, p0, iters=6, lr=0.3)
    np.testing.assert_allclose(np.asarray(t_new), np.asarray(t_old),
                               rtol=1e-6, atol=1e-9)
    # params agree to fusion-order noise (same arithmetic, different
    # program structure, so XLA may re-associate)
    np.testing.assert_allclose(np.asarray(p_new), np.asarray(p_old),
                               atol=2e-5)


# ------------------------------------------------- batched registration

def test_register_batch_stop_none_bit_identical():
    """stop=None must route to the exact fixed-iters program that omitting
    stop uses (bitwise-equal outputs, no steps array) — guarding against a
    future 'None = ConvergenceConfig(tol=0)'-style rerouting.  Parity with
    the *pre-PR* scan implementation is covered separately by
    test_adam_scan_trace_matches_old_closing_forward_impl."""
    F, M = _stack([0.5, 1.5])
    a = register_batch(F, M, **KW)
    b = register_batch(F, M, stop=None, **KW)
    np.testing.assert_array_equal(np.asarray(a.warped), np.asarray(b.warped))
    np.testing.assert_array_equal(np.asarray(a.params), np.asarray(b.params))
    np.testing.assert_array_equal(np.asarray(a.losses), np.asarray(b.losses))
    assert a.steps is None and b.steps is None


def test_register_batch_earlystop_quality_and_savings():
    """Acceptance: mixed easy/hard batch — early-stopped final losses
    within 2% of fixed-iters (easy lanes may be better) with measurably
    fewer Adam steps on the easy lanes."""
    F, M = _stack([0.3, 2.5, 0.3, 2.5])
    base = register_batch(F, M, **KW)
    res = register_batch(F, M, stop=STOP, **KW)
    assert res.steps is not None and res.steps.shape == (4, 2)
    steps = np.asarray(res.steps)
    budget = 2 * KW["iters"]
    # easy lanes (0, 2) stop measurably early; hard lanes may use it all
    assert steps[0].sum() < budget / 2
    assert steps[2].sum() < budget / 2
    assert steps.sum() < 4 * budget  # net batch saving
    excess = np.asarray(res.losses[:, -1]) / np.asarray(base.losses[:, -1])
    assert float(excess.max()) < 1.02  # within 2% of fixed-iters
    assert res.warped.shape == F.shape


def test_register_batch_masked_lanes_freeze():
    """A converged lane's params freeze at its own stopping point: the
    easy lane of a mixed batch finishes with the same params (and step
    count) as registering that pair alone under the same stop rule."""
    F, M = _stack([0.3, 2.5])
    both = register_batch(F, M, stop=STOP, **KW)
    solo = register_batch(F[:1], M[:1], stop=STOP, **KW)
    assert int(both.steps[0].sum()) == int(solo.steps[0].sum())
    assert int(both.steps[0].sum()) < int(both.steps[1].sum())
    np.testing.assert_allclose(np.asarray(both.params[0]),
                               np.asarray(solo.params[0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(both.warped[0]),
                               np.asarray(solo.warped[0]), atol=1e-5)


def test_ffd_register_stop_reports_steps():
    f, m, _ = make_pair(shape=SHAPE, tile=TILE, magnitude=0.3, seed=0)
    res = ffd_register(f, m, stop=STOP, **KW)
    assert isinstance(res.steps, list) and len(res.steps) == KW["levels"]
    assert all(1 <= s <= KW["iters"] for s in res.steps)
    assert sum(res.steps) < KW["levels"] * KW["iters"]  # easy pair stops


def test_register_batch_sharded_stop_matches_unsharded():
    """mesh= parity under early stopping (B=3 exercises pad lanes on any
    even device count; the filler lane mirrors the last real pair, so it
    converges with it and never extends the loop)."""
    F, M = _stack([0.3, 2.5, 0.6])
    base = register_batch(F, M, stop=STOP, **KW)
    res = register_batch(F, M, stop=STOP, mesh=make_registration_mesh(),
                         **KW)
    assert res.warped.shape == F.shape
    np.testing.assert_allclose(np.asarray(res.warped),
                               np.asarray(base.warped), atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.params),
                               np.asarray(base.params), atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.losses),
                               np.asarray(base.losses), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(res.steps),
                                  np.asarray(base.steps))


# ----------------------------------------------------- satellite fixes

def test_pad_batch_empty_raises():
    """Satellite: B=0 used to pad to an empty array (x[-1:] repeats
    nothing) and fail later with an opaque shape error."""
    with pytest.raises(ValueError, match="empty batch"):
        pad_batch(jnp.zeros((0, 4, 4, 4), jnp.float32), 4)
    with pytest.raises(ValueError, match="empty batch"):
        register_batch(jnp.zeros((0, 8, 8, 8)), jnp.zeros((0, 8, 8, 8)),
                       mode="separable", impl="jnp")


def test_ffd_level_loss_scores_bf16_inputs_in_fp32():
    """Satellite: a bf16 fixed volume must not drag the objective into
    bf16 — the similarity (and its trade-off against the fp32 bending
    term) is scored in fp32 regardless of input dtype."""
    fixed, moving, _ = make_pair(shape=(16, 14, 12), tile=(5, 5, 5),
                                 magnitude=1.0, seed=1)
    gshape = ffd.grid_shape_for_volume(fixed.shape, (5, 5, 5))
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.standard_normal(gshape + (3,)) * 0.1, jnp.float32)

    def loss_with(f, m):
        return ffd_level_loss(f, m, tile=(5, 5, 5), bending_weight=5e-3,
                              mode="separable", impl="jnp")(p)

    ref = loss_with(fixed, moving)
    lo = loss_with(fixed.astype(jnp.bfloat16), moving)
    assert lo.dtype == jnp.float32  # objective stays fp32
    # only the input quantisation differs — the scoring precision does not
    np.testing.assert_allclose(float(lo), float(ref), rtol=5e-3)


def test_register_batch_reports_compiled_flag():
    """Satellite: seconds no longer silently conflates compile time — the
    first call of a configuration flags compiled=True, the warm call
    doesn't (distinct stop= configs are distinct programs)."""
    F, M = _stack([0.8])
    kw = dict(tile=TILE, levels=1, iters=3, mode="separable", impl="jnp")
    stop = ConvergenceConfig(tol=1e-3, patience=2, max_iters=3)
    cold = register_batch(F, M, stop=stop, **kw)
    warm = register_batch(F, M, stop=stop, **kw)
    assert cold.compiled and not warm.compiled


def test_stop_rejects_bare_tolerance_floats():
    """Every entry point rejects the natural mistake of passing the
    tolerance directly (stop=1e-4) with a clear TypeError."""
    from repro.core.registration import affine_register

    f, m, _ = make_pair(shape=(12, 10, 8), tile=(4, 4, 4), magnitude=0.5,
                        seed=0)
    with pytest.raises(TypeError, match="ConvergenceConfig"):
        ffd_register(f, m, tile=(4, 4, 4), levels=1, iters=2,
                     mode="separable", impl="jnp", stop=1e-4)
    with pytest.raises(TypeError, match="ConvergenceConfig"):
        affine_register(f, m, iters=2, stop=1e-4)
    with pytest.raises(TypeError, match="ConvergenceConfig"):
        register_batch(f[None], m[None], tile=(4, 4, 4), levels=1, iters=2,
                       mode="separable", impl="jnp", stop=1e-4)


def test_autotune_rejects_stop():
    """Satellite: the tuner's timing workload pins stop=None — the winner
    must rank per-step cost, never a data-dependent loop length."""
    with pytest.raises(ValueError, match="stop"):
        autotune_bsi((8, 8, 8), (3, 3, 3), stop=ConvergenceConfig())
