"""Autotune disk-cache robustness: corrupt caches re-benchmark, never raise."""
import json

import pytest

from repro.engine import autotune
from repro.engine.autotune import autotune_bsi

GRID, TILE = (7, 7, 7), (2, 2, 2)


def _tune(cache):
    # the in-process memory cache would otherwise serve repeat calls before
    # the disk file is ever read — these tests exercise the DISK path
    autotune._MEM_CACHE.clear()
    return autotune_bsi(GRID, TILE, 2, reps=1, cache_path=str(cache),
                        candidates=(("ttli", "jnp"), ("separable", "jnp")))


@pytest.mark.parametrize("payload", [
    b"{ this is not json",          # garbage
    b'{"cpu|g7x7x7|t2x2x2|c2',      # truncated mid-write
    b"[1, 2, 3]",                   # valid JSON, wrong shape (not a dict)
    b"",                            # empty file
])
def test_corrupt_cache_triggers_clean_rebenchmark(tmp_path, payload):
    cache = tmp_path / "bsi_autotune.json"
    cache.write_bytes(payload)
    choice = _tune(cache)  # must not raise JSONDecodeError
    assert choice.mode in {"ttli", "separable"} and choice.us_per_call > 0
    # the re-benchmark rewrote the file as valid versioned JSON
    data = json.loads(cache.read_text())
    assert data["__schema__"] == autotune.SCHEMA_VERSION
    assert isinstance(data["entries"], dict) and len(data["entries"]) == 1


def test_stale_schema_cache_is_a_miss_not_an_error(tmp_path):
    """A disk cache written before the fused axis existed (SCHEMA_VERSION
    bump) must read as a clean miss — re-benchmark and rewrite — never a
    KeyError or a choice silently mis-dispatched with default fields."""
    cache = tmp_path / "bsi_autotune.json"
    # the v1 layout: a flat {key: choice} dict, no __schema__ wrapper
    stale_key = ("cpu|g7x7x7|t2x2x2|c2|"
                 "ttli/jnp,separable/jnp")
    cache.write_text(json.dumps({
        stale_key: {"mode": "ttli", "impl": "jnp", "us_per_call": 1.0}}))
    assert autotune._load_disk(str(cache)) == {}
    choice = _tune(cache)  # re-benchmarks instead of trusting the v1 entry
    assert choice.mode in {"ttli", "separable"} and choice.us_per_call > 0
    data = json.loads(cache.read_text())  # ... and upgraded the file
    assert data["__schema__"] == autotune.SCHEMA_VERSION
    # a future schema is equally a miss (no partial decode of unknown layouts)
    cache.write_text(json.dumps(
        {"__schema__": autotune.SCHEMA_VERSION + 1, "entries": {"k": {}}}))
    assert autotune._load_disk(str(cache)) == {}


def test_pre_matmul_v2_cache_is_a_miss_and_upgrades(tmp_path):
    """A v2 (pre-matmul) cache pinned winners measured without the MXU form
    in the race: the v3 bump must read it as a clean miss, re-benchmark with
    the enlarged candidate space and rewrite the file under v3."""
    assert autotune.SCHEMA_VERSION == 3  # this test documents the v2 -> v3 bump
    cache = tmp_path / "bsi_autotune.json"
    stale_key = "cpu|g7x7x7|t2x2x2|c2|ttli/jnp,separable/jnp"
    cache.write_text(json.dumps({
        "__schema__": 2,
        "entries": {stale_key: {"mode": "ttli", "impl": "jnp",
                                "us_per_call": 1.0, "grad_impl": "xla",
                                "fused": "off"}}}))
    assert autotune._load_disk(str(cache)) == {}  # well-formed v2 != a hit
    choice = _tune(cache)
    assert choice.mode in {"ttli", "separable"} and choice.us_per_call > 0
    data = json.loads(cache.read_text())
    assert data["__schema__"] == 3  # the rewrite upgraded the schema
    # the v2 entry did not survive into the rewritten file
    assert all(v.get("us_per_call") != 1.0 for v in data["entries"].values())


def test_malformed_entry_is_a_miss_not_an_error(tmp_path):
    cache = tmp_path / "bsi_autotune.json"
    first = _tune(cache)
    data = json.loads(cache.read_text())
    (key,) = data["entries"]
    # hand-edit the entry into nonsense: missing fields / wrong types
    for bad in ({}, {"mode": "ttli"}, {"mode": "ttli", "impl": "jnp",
                                       "us_per_call": "fast"},
                {"mode": "ttli", "impl": "jnp", "us_per_call": 1.0,
                 "fused": "sideways"}, "zap"):
        cache.write_text(json.dumps({"__schema__": autotune.SCHEMA_VERSION,
                                     "entries": {key: bad}}))
        again = _tune(cache)  # re-measures; winner may differ (timing noise)
        assert again.mode in {"ttli", "separable"} and again.us_per_call > 0
    assert first.us_per_call > 0


def test_valid_cache_entry_still_round_trips(tmp_path):
    cache = tmp_path / "bsi_autotune.json"
    first = _tune(cache)
    # rewrite the file as-is; a fresh read must serve the stored choice
    data = json.loads(cache.read_text())
    cache.write_text(json.dumps(data))
    assert _tune(cache) == first


def test_per_similarity_cache_keys_are_distinct(tmp_path):
    """measure_grad timing is per-similarity: nmi's backward is a different
    workload mix than ssd's, so each gets its own cache entry."""
    cache = tmp_path / "bsi_autotune.json"
    for sim in ("ssd", "nmi"):
        choice = autotune_bsi(GRID, TILE, 3, reps=1, cache_path=str(cache),
                              candidates=(("ttli", "jnp"),
                                          ("separable", "jnp")),
                              measure_grad=True, similarity=sim)
        assert choice.us_per_call > 0
    entries = json.loads((cache).read_text())["entries"]
    assert len(entries) == 2
    assert any("|sim=ssd|" in k for k in entries)
    assert any("|sim=nmi|" in k for k in entries)


def test_fused_race_entry_round_trips(tmp_path, monkeypatch):
    """autotune_fused caches its decision under the current schema and serves
    it back without re-measuring (us_per_call would differ on a re-race)."""
    # force the actual measurement on CPU hosts (same override that admits
    # interpret-mode Pallas into default_candidates)
    monkeypatch.setenv("REPRO_AUTOTUNE_PALLAS", "1")
    cache = tmp_path / "bsi_autotune.json"
    base = autotune.BsiChoice("separable", "jnp", 0.0, "jnp")
    autotune._MEM_CACHE.clear()
    first = autotune.autotune_fused(GRID, TILE, (8, 8, 8), base=base,
                                    similarity="ssd", reps=1,
                                    cache_path=str(cache))
    assert first.fused in ("on", "off") and first.us_per_call > 0
    autotune._MEM_CACHE.clear()
    again = autotune.autotune_fused(GRID, TILE, (8, 8, 8), base=base,
                                    similarity="ssd", reps=1,
                                    cache_path=str(cache))
    assert again == first
    entries = json.loads(cache.read_text())["entries"]
    assert any("|fused|" in k for k in entries)
