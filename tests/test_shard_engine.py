"""Mesh-sharded register_batch: rules, mesh helper, pad/strip, parity.

The in-process tests adapt to however many devices the process has — 1 in
the plain CI tests job, 8 in the ``multi-device`` job (which exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  The subprocess
test pins the 8-device layout so the acceptance path is exercised even in a
single-device run.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.data.volumes import make_pair
from repro.distributed.sharding import REGISTRATION_RULES
from repro.engine import make_registration_mesh, register_batch
from repro.engine.shard import (GRID_AXES, LOSS_AXES, VOLUME_AXES,
                                batch_mask, batch_multiple,
                                compile_sharded_batch, pad_batch)

TILE = (6, 6, 6)
SHAPE = (24, 20, 18)


def _stack(n):
    pairs = [make_pair(shape=SHAPE, tile=TILE, magnitude=1.5, seed=s)
             for s in range(n)]
    return (jnp.stack([p[0] for p in pairs]),
            jnp.stack([p[1] for p in pairs]))


def test_registration_rules_batch_over_data():
    r = REGISTRATION_RULES(("data",))
    assert r.spec(VOLUME_AXES) == PS(("data",), None, None, None)
    assert r.spec(GRID_AXES) == PS(("data",), None, None, None, None)
    assert r.spec(LOSS_AXES) == PS(("data",), None)
    # a pod axis folds into the batch shards, like TRAIN_RULES' batch
    assert REGISTRATION_RULES(("pod", "data"))["batch"] == ("pod", "data")


def test_make_registration_mesh_defaults_and_errors():
    mesh = make_registration_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == len(jax.devices())
    assert batch_multiple(mesh) == len(jax.devices())
    assert make_registration_mesh(1).shape["data"] == 1
    with pytest.raises(ValueError):
        make_registration_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        make_registration_mesh(0)


def test_pad_batch_and_mask_roundtrip():
    x = jnp.arange(6, dtype=jnp.float32).reshape(3, 2)
    padded, b = pad_batch(x, 4)
    assert padded.shape == (4, 2) and b == 3
    np.testing.assert_array_equal(np.asarray(padded[:b]), np.asarray(x))
    # pad rows repeat the last real pair, not zeros
    np.testing.assert_array_equal(np.asarray(padded[3]), np.asarray(x[2]))
    np.testing.assert_array_equal(
        np.asarray(batch_mask(b, padded.shape[0])),
        np.array([True, True, True, False]))
    # already-divisible batches pass through untouched
    same, b2 = pad_batch(x, 3)
    assert same.shape == (3, 2) and b2 == 3
    assert bool(batch_mask(b2, same.shape[0]).all())


def test_registration_sharding_places_batch_over_all_devices():
    """REGISTRATION_RULES + NamedSharding split a stack across every local
    device (1 in the plain job, 8 in the multi-device job)."""
    mesh = make_registration_mesh()
    n = mesh.shape["data"]
    spec = REGISTRATION_RULES(mesh.axis_names).spec(VOLUME_AXES)
    x = jnp.zeros((2 * n, 4, 4, 4), jnp.float32)
    y = jax.device_put(x, NamedSharding(mesh, spec))
    assert len({s.device for s in y.addressable_shards}) == n


def test_register_batch_b1():
    F, M = _stack(1)
    res = register_batch(F, M, tile=TILE, levels=1, iters=3,
                         mode="separable", impl="jnp")
    assert res.warped.shape == F.shape
    assert res.params.shape[0] == 1
    assert res.losses.shape == (1, 1)


def test_register_batch_mesh_matches_unsharded():
    """mesh= parity: B=3 is non-divisible for any even device count, so the
    pad+strip round-trip is exercised wherever this runs on >1 device."""
    F, M = _stack(3)
    kw = dict(tile=TILE, levels=2, iters=4, mode="separable", impl="jnp")
    base = register_batch(F, M, **kw)
    mesh = make_registration_mesh()
    res = register_batch(F, M, mesh=mesh, **kw)
    assert res.warped.shape == F.shape  # padding stripped on return
    assert res.params.shape == base.params.shape
    assert res.losses.shape == base.losses.shape
    np.testing.assert_allclose(np.asarray(res.warped),
                               np.asarray(base.warped), atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.params),
                               np.asarray(base.params), atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.losses),
                               np.asarray(base.losses),
                               rtol=1e-4, atol=1e-6)


def test_register_batch_b1_with_mesh():
    """B=1 pads up to the full device count and still strips back to 1."""
    F, M = _stack(1)
    kw = dict(tile=TILE, levels=1, iters=3, mode="separable", impl="jnp")
    base = register_batch(F, M, **kw)
    res = register_batch(F, M, mesh=make_registration_mesh(), **kw)
    assert res.warped.shape == F.shape
    np.testing.assert_allclose(np.asarray(res.warped),
                               np.asarray(base.warped), atol=1e-4)


def test_register_batch_mesh_rejects_bad_shapes():
    mesh = make_registration_mesh()
    v = jnp.zeros((8, 8, 8), jnp.float32)
    with pytest.raises(ValueError):
        register_batch(v, v, mesh=mesh)  # fixed.ndim != 4
    with pytest.raises(ValueError):
        register_batch(jnp.zeros((2, 8, 8, 8)), jnp.zeros((3, 8, 8, 8)),
                       mesh=mesh)


def test_compiled_sharded_outputs_stay_distributed():
    """out_shardings keep results on the mesh (no gather to one device)."""
    mesh = make_registration_mesh()
    n = mesh.shape["data"]
    fn = compile_sharded_batch(mesh, TILE, 1, 2, 0.5, 5e-3,
                               "separable", "jnp", "ssd")
    F, M = _stack(1)
    F = jnp.concatenate([F] * n, axis=0)
    M = jnp.concatenate([M] * n, axis=0)
    warped, phi, losses = fn(F, M)
    for out in (warped, phi, losses):
        assert len({s.device for s in out.addressable_shards}) == n


def test_sharded_8dev_subprocess():
    """Acceptance: 8 fake CPU devices, non-divisible B=3 and B=1, sharded ==
    unsharded to 1e-4 (runs in a fresh process so it holds even when the
    parent has a single device)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import jax, jax.numpy as jnp, numpy as np
        from repro.data.volumes import make_pair
        from repro.engine import register_batch, make_registration_mesh
        assert jax.device_count() == 8, jax.devices()
        pairs = [make_pair(shape=(18, 16, 14), tile=(5, 5, 5),
                           magnitude=1.2, seed=s) for s in range(3)]
        F = jnp.stack([p[0] for p in pairs])
        M = jnp.stack([p[1] for p in pairs])
        kw = dict(tile=(5, 5, 5), levels=2, iters=4,
                  mode="separable", impl="jnp")
        base = register_batch(F, M, **kw)
        mesh = make_registration_mesh()
        res = register_batch(F, M, mesh=mesh, **kw)
        assert res.warped.shape == F.shape
        np.testing.assert_allclose(np.asarray(res.warped),
                                   np.asarray(base.warped), atol=1e-4)
        np.testing.assert_allclose(np.asarray(res.params),
                                   np.asarray(base.params), atol=1e-4)
        np.testing.assert_allclose(np.asarray(res.losses),
                                   np.asarray(base.losses),
                                   rtol=1e-4, atol=1e-6)
        r1 = register_batch(F[:1], M[:1], mesh=mesh, **kw)
        b1 = register_batch(F[:1], M[:1], **kw)
        np.testing.assert_allclose(np.asarray(r1.warped),
                                   np.asarray(b1.warped), atol=1e-4)
        print("SHARD_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)  # the child pins its own before jax imports
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "SHARD_OK" in r.stdout, r.stderr[-2000:]
