"""RegistrationOptions: validation, hashability, and the deprecation shim.

The API-consolidation contract (PR 6): every entry point configures through
one frozen ``RegistrationOptions``; the legacy keyword spelling still works,
warns once per call site, and produces *bit-identical* results (both paths
build the same options object, hence hit the same compiled-runner cache).
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.options import (UNSET, RegistrationOptions,
                                _reset_deprecation_registry,
                                merge_legacy_options)
from repro.engine.convergence import ConvergenceConfig

SHAPE = (18, 16, 14)


def _pair(seed=0):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=SHAPE).astype(np.float32)
    return f, np.roll(f, 1, axis=0)


SMALL = dict(tile=(6, 6, 6), levels=2, iters=4, lr=0.1,
             mode="separable", impl="jnp", grad_impl="xla")


class TestValidation:
    def test_defaults_match_legacy_ffd_signature(self):
        from repro.core.regularizer import NoRegularizer
        from repro.core.transform import DisplacementTransform

        o = RegistrationOptions()
        assert (o.tile, o.levels, o.iters, o.lr) == ((5, 5, 5), 2, 40, 0.5)
        assert o.bending_weight == 5e-3
        assert (o.mode, o.impl, o.grad_impl) == ("auto", "auto", "auto")
        assert o.similarity == "ssd" and o.stop is None
        # the new axes default to the historical behaviour (classic FFD,
        # legacy bending proxy), normalised to their spec instances
        assert o.transform == DisplacementTransform()
        assert o.regularizer == NoRegularizer()

    def test_tile_coerced_to_int_tuple(self):
        assert RegistrationOptions(tile=[6.0, 5, 4]).tile == (6, 5, 4)

    @pytest.mark.parametrize("bad", [
        dict(tile=(5, 5)), dict(tile=(5, 5, 0)), dict(levels=0),
        dict(iters=0), dict(lr=0.0), dict(lr=-1.0),
        dict(bending_weight=-1e-3),
    ])
    def test_value_errors(self, bad):
        with pytest.raises(ValueError):
            RegistrationOptions(**bad)

    @pytest.mark.parametrize("bad", [
        dict(mode="nope"), dict(impl="cuda"), dict(grad_impl="nope"),
        dict(transform="affine"), dict(regularizer="tv"),
        dict(fused="on", transform="velocity"),
    ])
    def test_backend_name_errors(self, bad):
        with pytest.raises(ValueError):
            RegistrationOptions(**bad)

    def test_transform_regularizer_normalise_to_specs(self):
        from repro.core.regularizer import BendingRegularizer, bending
        from repro.core.transform import VelocityTransform, velocity

        o = RegistrationOptions(transform="velocity", regularizer="bending")
        assert isinstance(o.transform, VelocityTransform)
        assert isinstance(o.regularizer, BendingRegularizer)
        # name and factory spellings hash equal -> one program cache entry
        p = RegistrationOptions(transform=velocity(),
                                regularizer=bending())
        assert o == p and hash(o) == hash(p)
        # parameterised variants are distinct keys
        q = RegistrationOptions(transform=velocity(squarings=3),
                                regularizer=bending(weight=1e-2))
        assert q != o and q.transform.squarings == 3
        assert q.regularizer.weight == 1e-2

    def test_stop_type_error(self):
        with pytest.raises(TypeError):
            RegistrationOptions(stop=1e-4)  # the classic tol-not-config slip

    def test_similarity_type_error(self):
        with pytest.raises(TypeError):
            RegistrationOptions(similarity=3)

    def test_compute_dtype_canonicalised(self):
        assert RegistrationOptions(
            compute_dtype=jnp.bfloat16).compute_dtype == "bfloat16"

    def test_hashable_and_cache_key_worthy(self):
        a = RegistrationOptions(tile=(6, 6, 6), stop=ConvergenceConfig())
        b = RegistrationOptions(tile=[6, 6, 6], stop=ConvergenceConfig())
        assert a == b and hash(a) == hash(b)
        assert len({a: 1, b: 2}) == 1

    def test_normalized_resolves_similarity_and_stop(self):
        from repro.core.similarity import resolve_similarity

        _, ssd = resolve_similarity("ssd")
        o = RegistrationOptions(similarity=ssd, iters=7,
                                stop=ConvergenceConfig()).normalized()
        assert o.similarity == "ssd"          # callable -> registry key
        assert o.stop.max_iters == 7          # inherits iters

    def test_for_affine_pins_ffd_fields(self):
        o = RegistrationOptions(tile=(9, 9, 9), levels=3, iters=5,
                                lr=0.02, compute_dtype="bfloat16")
        a = o.for_affine()
        base = RegistrationOptions()
        assert (a.iters, a.lr) == (5, 0.02)   # affine-relevant fields kept
        assert a.tile == base.tile and a.levels == base.levels
        assert a.compute_dtype is None

    def test_for_affine_pins_transform_and_regularizer(self):
        o = RegistrationOptions(transform="velocity", regularizer="bending")
        a = o.for_affine()
        base = RegistrationOptions()
        assert a.transform == base.transform
        assert a.regularizer == base.regularizer


class TestDeprecationShim:
    def test_mixing_options_and_kwargs_raises(self):
        with pytest.raises(TypeError, match="not both"):
            merge_legacy_options("fn", RegistrationOptions(),
                                 dict(iters=3, lr=UNSET))

    def test_non_options_object_raises(self):
        with pytest.raises(TypeError, match="RegistrationOptions"):
            merge_legacy_options("fn", {"iters": 3}, dict(iters=UNSET))

    def test_options_pass_through_unwarned(self):
        o = RegistrationOptions(iters=3)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert merge_legacy_options(
                "fn", o, dict(iters=UNSET, lr=UNSET)) is o

    def test_warns_once_per_call_site(self):
        _reset_deprecation_registry()

        def call_site():
            return merge_legacy_options("fn", None,
                                        dict(iters=3, lr=UNSET),
                                        stacklevel=2)

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            for _ in range(3):
                call_site()                   # one site, three calls
            merge_legacy_options("fn", None, dict(iters=3, lr=UNSET),
                                 stacklevel=2)  # a second, distinct site
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(deps) == 2
        assert "iters" in str(deps[0].message)

    def test_warning_names_the_passed_fields(self):
        _reset_deprecation_registry()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            merge_legacy_options(
                "fn", None, dict(iters=3, transform="velocity", lr=UNSET),
                stacklevel=2)
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(deps) == 1
        # the suggested replacement spells out the fields actually passed
        msg = str(deps[0].message)
        assert "RegistrationOptions(iters=..., transform=...)" in msg

    def test_make_adam_runner_requires_a_config(self):
        from repro.engine.loop import make_adam_runner

        with pytest.raises(TypeError, match="options=RegistrationOptions"):
            make_adam_runner(lambda: None)
        # either spelling satisfies it (legacy path warns as usual)
        make_adam_runner(lambda: None,
                         options=RegistrationOptions(iters=2, lr=0.1))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            make_adam_runner(lambda: None, iters=2, lr=0.1)

    def test_legacy_kwargs_overlay_defaults(self):
        _reset_deprecation_registry()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            o = merge_legacy_options(
                "fn", None, dict(iters=9, lr=UNSET),
                defaults=RegistrationOptions(iters=60, lr=0.02))
        assert (o.iters, o.lr) == (9, 0.02)


class TestBitwiseEquivalence:
    """kwarg path == options path, bit for bit (they share one program)."""

    def test_ffd_register(self):
        from repro.core.registration import ffd_register

        f, m = _pair()
        _reset_deprecation_registry()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            legacy = ffd_register(f, m, **SMALL)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
        viaopts = ffd_register(f, m, options=RegistrationOptions(**SMALL))
        assert np.array_equal(np.asarray(legacy.warped),
                              np.asarray(viaopts.warped))
        assert np.array_equal(np.asarray(legacy.params),
                              np.asarray(viaopts.params))
        assert legacy.losses == viaopts.losses

    def test_ffd_register_with_stop(self):
        from repro.core.registration import ffd_register

        f, m = _pair(1)
        stop = ConvergenceConfig(tol=3e-4, patience=3)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            legacy = ffd_register(f, m, stop=stop, **SMALL)
        viaopts = ffd_register(
            f, m, options=RegistrationOptions(stop=stop, **SMALL))
        assert legacy.steps == viaopts.steps
        assert np.array_equal(np.asarray(legacy.warped),
                              np.asarray(viaopts.warped))

    def test_affine_register(self):
        from repro.core.registration import affine_register

        f, m = _pair(2)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            legacy = affine_register(f, m, iters=4, lr=0.01)
        viaopts = affine_register(
            f, m, options=RegistrationOptions(iters=4, lr=0.01))
        assert np.array_equal(np.asarray(legacy.warped),
                              np.asarray(viaopts.warped))
        assert legacy.losses == viaopts.losses

    def test_register_batch(self):
        from repro.engine.batch import register_batch

        f0, m0 = _pair(3)
        f1, m1 = _pair(4)
        F, M = np.stack([f0, f1]), np.stack([m0, m1])
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            legacy = register_batch(F, M, **SMALL)
        viaopts = register_batch(F, M, options=RegistrationOptions(**SMALL))
        assert np.array_equal(np.asarray(legacy.warped),
                              np.asarray(viaopts.warped))

    def test_ffd_register_transform_regularizer_kwargs(self):
        """The legacy-kwarg spelling covers the new fields, bit for bit."""
        from repro.core.registration import ffd_register

        f, m = _pair(5)
        _reset_deprecation_registry()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            legacy = ffd_register(f, m, transform="velocity",
                                  regularizer="bending", **SMALL)
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert deps and "transform" in str(deps[0].message)
        viaopts = ffd_register(f, m, options=RegistrationOptions(
            transform="velocity", regularizer="bending", **SMALL))
        assert np.array_equal(np.asarray(legacy.warped),
                              np.asarray(viaopts.warped))
        assert np.array_equal(np.asarray(legacy.params),
                              np.asarray(viaopts.params))
        assert legacy.losses == viaopts.losses

    def test_mixing_raises_at_entry_points(self):
        from repro.core.registration import ffd_register

        f, m = _pair()
        with pytest.raises(TypeError, match="not both"):
            ffd_register(f, m, options=RegistrationOptions(), iters=3)

    def test_options_is_the_cache_key(self):
        """Same options object -> same compiled level runner (cache hit)."""
        from repro.core.registration import _ffd_level_runner
        from repro.engine.autotune import resolve_options

        opts = resolve_options(RegistrationOptions(**SMALL), SHAPE)
        r1 = _ffd_level_runner(SHAPE, opts)
        r2 = _ffd_level_runner(SHAPE, resolve_options(
            RegistrationOptions(**SMALL), SHAPE))
        assert r1 is r2
