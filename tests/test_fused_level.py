"""Fused level-step megakernel parity: fused == unfused everywhere it runs.

The fused path (``core.ffd.fused_warp_loss`` -> ``kernels.bsi_fused``)
evaluates BSI + warp + similarity in one VMEM pass; these tests pin it to
the unfused dense-field -> warp -> similarity composition — loss AND
gradient — across all four registered similarities, non-divisible tile
shapes, reduced compute dtypes, ``vmap`` (``register_batch``), a device
mesh, and the early-stopped convergence loop.  The gradient parity is exact
by construction (the custom VJP differentiates the unfused composition) —
what these tests actually guard is the fused *forward*.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ffd
from repro.core.options import RegistrationOptions
from repro.core.registration import ffd_register
from repro.core.similarity import resolve_similarity
from repro.engine import ConvergenceConfig, register_batch
from repro.engine.autotune import resolve_options

SIMS = ("ssd", "ncc", "lncc", "nmi")
VOL = (12, 11, 9)
TILE = (3, 3, 3)


def _data(vol=VOL, seed=0):
    rng = np.random.default_rng(seed)
    g = ffd.grid_shape_for_volume(vol, TILE)
    phi = jnp.asarray(0.8 * rng.standard_normal(g + (3,)), jnp.float32)
    mov = jnp.asarray(rng.random(vol), jnp.float32)
    fix = jnp.asarray(rng.random(vol), jnp.float32)
    return phi, mov, fix


def _unfused(phi, mov, fix, tile, vol, sim, compute_dtype=None):
    _, sim_fn = resolve_similarity(sim)
    disp = ffd.dense_field(phi, tile, vol, compute_dtype=compute_dtype)
    warped = ffd.warp_volume(mov, disp, compute_dtype=compute_dtype)
    return sim_fn(warped.astype(jnp.float32), fix)


@pytest.mark.parametrize("sim", SIMS)
def test_fused_matches_unfused_loss_and_grad(sim):
    phi, mov, fix = _data()

    def fused(p):
        return ffd.fused_warp_loss(p, mov, fix, TILE, similarity=sim)

    def unfused(p):
        return _unfused(p, mov, fix, TILE, VOL, sim)

    lf, gf = jax.value_and_grad(fused)(phi)
    lu, gu = jax.value_and_grad(unfused)(phi)
    assert abs(float(lf) - float(lu)) <= 1e-5 * max(1.0, abs(float(lu)))
    assert float(jnp.max(jnp.abs(gf - gu))) <= 1e-5


@pytest.mark.parametrize("vol,tile", [
    ((7, 6, 5), (2, 3, 4)),     # every axis a different, non-divisible tile
    ((13, 10, 9), (4, 4, 4)),   # grid overhangs the volume on two axes
])
@pytest.mark.parametrize("sim", ("ssd", "lncc"))  # lncc exercises the halo
def test_fused_non_divisible_tiles(vol, tile, sim):
    rng = np.random.default_rng(1)
    g = ffd.grid_shape_for_volume(vol, tile)
    phi = jnp.asarray(rng.standard_normal(g + (3,)), jnp.float32)
    mov = jnp.asarray(rng.random(vol), jnp.float32)
    fix = jnp.asarray(rng.random(vol), jnp.float32)
    lf = ffd.fused_warp_loss(phi, mov, fix, tile, similarity=sim)
    lu = _unfused(phi, mov, fix, tile, vol, sim)
    assert abs(float(lf) - float(lu)) <= 1e-5 * max(1.0, abs(float(lu)))


@pytest.mark.parametrize("sim", SIMS)
def test_fused_bf16_compute_dtype(sim):
    """bf16 forward stays close to the fp32 reference; the adjoint (and the
    loss itself) accumulate in fp32, so gradients come back finite fp32."""
    phi, mov, fix = _data(seed=2)

    def fused(p):
        return ffd.fused_warp_loss(p, mov, fix, TILE, similarity=sim,
                                   compute_dtype="bfloat16")

    l16, g16 = jax.value_and_grad(fused)(phi)
    l32 = ffd.fused_warp_loss(phi, mov, fix, TILE, similarity=sim)
    # bf16 quantisation of the field/warp shifts the loss by O(1e-3) rel
    assert abs(float(l16) - float(l32)) <= 3e-3 * max(1.0, abs(float(l32)))
    assert g16.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(g16)))
    # and the bf16 fused forward matches the bf16 UNfused forward tightly —
    # same quantisation points, so the kernel itself adds no extra error
    lu16 = _unfused(phi, mov, fix, TILE, VOL, sim, compute_dtype="bfloat16")
    assert abs(float(l16) - float(lu16)) <= 1e-4 * max(1.0, abs(float(lu16)))


def test_register_batch_fused_parity_under_vmap():
    rng = np.random.default_rng(3)
    F = jnp.asarray(rng.random((2,) + VOL), jnp.float32)
    M = jnp.asarray(rng.random((2,) + VOL), jnp.float32)
    kw = dict(tile=TILE, levels=1, iters=4, mode="separable", impl="jnp",
              grad_impl="xla")
    on = register_batch(F, M, options=RegistrationOptions(**kw, fused="on"))
    off = register_batch(F, M, options=RegistrationOptions(**kw, fused="off"))
    assert float(jnp.max(jnp.abs(on.warped - off.warped))) <= 1e-5
    assert float(jnp.max(jnp.abs(on.losses - off.losses))) <= 1e-5


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (run the multi-device CI job)")
def test_sharded_fused_matches_unsharded():
    from repro.engine import make_registration_mesh

    rng = np.random.default_rng(4)
    n = len(jax.devices())
    F = jnp.asarray(rng.random((n,) + VOL), jnp.float32)
    M = jnp.asarray(rng.random((n,) + VOL), jnp.float32)
    opts = RegistrationOptions(tile=TILE, levels=1, iters=4,
                               mode="separable", impl="jnp",
                               grad_impl="xla", fused="on")
    sharded = register_batch(F, M, options=opts,
                             mesh=make_registration_mesh(n))
    single = register_batch(F, M, options=opts)
    assert float(jnp.max(jnp.abs(jnp.asarray(sharded.warped)
                                 - jnp.asarray(single.warped)))) <= 1e-5


def test_fused_convergence_stop_parity():
    """Early stopping sees identical per-step losses either way, so the
    fused and unfused runs must stop at the same step with the same loss."""
    rng = np.random.default_rng(5)
    fix = jnp.asarray(rng.random(VOL), jnp.float32)
    mov = jnp.asarray(rng.random(VOL), jnp.float32)
    kw = dict(tile=TILE, levels=1, iters=12, lr=0.1, mode="separable",
              impl="jnp", grad_impl="xla",
              stop=ConvergenceConfig(tol=1e-3, patience=3))
    on = ffd_register(fix, mov, options=RegistrationOptions(**kw, fused="on"))
    off = ffd_register(fix, mov,
                       options=RegistrationOptions(**kw, fused="off"))
    assert on.steps == off.steps
    np.testing.assert_allclose(on.losses, off.losses, atol=1e-5)


def test_fused_on_with_custom_similarity_raises():
    def my_sim(w, f):
        return jnp.mean((w - f) ** 2)

    opts = RegistrationOptions(tile=TILE, levels=1, iters=2,
                               mode="separable", impl="jnp",
                               grad_impl="xla", similarity=my_sim,
                               fused="on")
    with pytest.raises(ValueError, match="fused"):
        resolve_options(opts, VOL)


def test_fused_bool_spelling_normalises():
    assert RegistrationOptions(fused=True).fused == "on"
    assert RegistrationOptions(fused=False).fused == "off"
    with pytest.raises(ValueError):
        RegistrationOptions(fused="sideways")


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="the interpret-mode exclusion only applies on CPU")
def test_fused_auto_resolves_off_on_cpu(tmp_path, monkeypatch):
    """On CPU hosts the fused kernel only runs under interpret=True — a
    correctness path — so fused="auto" must resolve to the unfused step
    without even paying for a measurement."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE_PALLAS", raising=False)
    opts = RegistrationOptions(tile=TILE, levels=1, iters=2,
                               mode="separable", impl="jnp", grad_impl="xla",
                               fused="auto")
    resolved = resolve_options(opts, (20, 20, 20))
    assert resolved.fused == "off"
