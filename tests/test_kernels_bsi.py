"""Pallas BSI kernels vs the pure-jnp oracle: shape/dtype sweeps (interpret)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import bsi_ref

KERNEL_MODES = ("tt", "ttli", "separable", "matmul")

SHAPE_SWEEP = [
    # (grid points per axis, tile)
    ((7, 6, 5), (5, 4, 3)),
    ((9, 9, 9), (5, 5, 5)),      # paper's default tile
    ((4, 4, 4), (3, 3, 3)),      # single tile per axis, smallest tile
    ((11, 4, 6), (7, 7, 7)),     # paper's largest tile, non-cubic grid
    ((12, 12, 5), (6, 6, 6)),
    ((5, 13, 9), (4, 6, 5)),     # mixed tile
]


@pytest.mark.parametrize("mode", KERNEL_MODES)
@pytest.mark.parametrize("grid,tile", SHAPE_SWEEP)
def test_kernel_matches_oracle(mode, grid, tile):
    rng = np.random.default_rng(hash((grid, tile)) % 2**31)
    phi = jnp.asarray(rng.standard_normal(grid + (3,)), jnp.float32)
    ref = bsi_ref(phi, tile)
    out = ops.bsi_pallas(phi, tile, mode=mode)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


@pytest.mark.parametrize("mode", KERNEL_MODES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(mode, dtype):
    rng = np.random.default_rng(3)
    phi = jnp.asarray(rng.standard_normal((7, 7, 7, 3)), dtype)
    ref = bsi_ref(phi.astype(jnp.float32), (5, 5, 5))
    out = ops.bsi_pallas(phi, (5, 5, 5), mode=mode)
    atol = 3e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=atol
    )


@pytest.mark.parametrize("mode", KERNEL_MODES)
def test_kernel_channels(mode):
    # deformation fields are C=3, but the kernels are generic (paper §8: BSI
    # as generic interpolation, e.g. image zoom with C=1).
    for c in (1, 2, 4):
        rng = np.random.default_rng(c)
        phi = jnp.asarray(rng.standard_normal((6, 6, 6, c)), jnp.float32)
        ref = bsi_ref(phi, (4, 4, 4))
        out = ops.bsi_pallas(phi, (4, 4, 4), mode=mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


@pytest.mark.parametrize("block_tiles", [(1, 1, 1), (2, 2, 2), (4, 2, 1)])
def test_kernel_block_shapes(block_tiles):
    rng = np.random.default_rng(7)
    phi = jnp.asarray(rng.standard_normal((8, 8, 8, 3)), jnp.float32)
    ref = bsi_ref(phi, (5, 5, 5))
    out = ops.bsi_pallas(phi, (5, 5, 5), mode="ttli", block_tiles=block_tiles)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_default_interpret_resolves_from_backend(monkeypatch):
    """interpret defaults per-backend: compiled on TPU, interpreter elsewhere
    — callers never thread the flag."""
    import jax

    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert ops.default_interpret() is False
    for backend in ("cpu", "gpu"):
        monkeypatch.setattr(jax, "default_backend", lambda b=backend: b)
        assert ops.default_interpret() is True


def test_bsi_pallas_runs_without_interpret_flag():
    # on the CPU test backend the default must resolve to interpret mode
    rng = np.random.default_rng(0)
    phi = jnp.asarray(rng.standard_normal((6, 6, 6, 3)), jnp.float32)
    out = ops.bsi_pallas(phi, (4, 4, 4), mode="ttli")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(bsi_ref(phi, (4, 4, 4))), atol=3e-6)


def test_pick_block_tiles_respects_budget():
    bt = ops.pick_block_tiles((64, 64, 64), (7, 7, 7), 3, 4, budget=1 * 2**20)
    dx, dy, dz = 7, 7, 7
    out_bytes = bt[0] * dx * bt[1] * dy * bt[2] * dz * 3 * 4
    assert out_bytes < 1 * 2**20


def test_pick_block_tiles_clamps_to_tiny_grids():
    """num_tiles is honoured: a grid smaller than the default block must not
    budget for (and pad up to) blocks larger than the whole grid."""
    assert ops.pick_block_tiles((2, 1, 3), (5, 5, 5), 3, 4) == (2, 1, 3)
    # clamping also frees budget: a tiny grid keeps its axes un-halved even
    # under a budget that would shrink the default 4^3 block
    bt = ops.pick_block_tiles((1, 1, 64), (7, 7, 7), 3, 4, budget=2**20)
    assert bt[0] == 1 and bt[1] == 1
    # and the padded kernel path agrees with the oracle on such grids
    rng = np.random.default_rng(11)
    phi = jnp.asarray(rng.standard_normal((5, 4, 6, 3)), jnp.float32)
    out = ops.bsi_pallas(phi, (4, 4, 4), mode="separable")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(bsi_ref(phi, (4, 4, 4))), atol=3e-6)


def test_op_count_model():
    """Paper App. B: 255 ops/voxel (TT) vs 126 (TTLI) vs separable.

    Counted per scalar output on the weighted-sum DAG:
      TT:   64 summands * (3 mults + 1 add) - 1 = 255
      TTLI: 63 lerps * 2 ops = 126
      separable: per-axis sweeps, 4 MACs per intermediate element.
    """
    tt = 64 * (3 + 1) - 1
    ttli = (8 * 7 + 7) * 2
    assert tt == 255 and ttli == 126
    # separable MACs per tile of d^3 voxels: each sweep output costs 4 MACs;
    # x sweep has d*4*4 outputs, y sweep d*d*4, z sweep d^3.
    d = 5
    sep = 4 * (d * 4 * 4) + 4 * (d * d * 4) + 4 * d**3
    naive = 64 * d**3
    assert sep == 1220 and naive == 8000
    assert naive / sep > 6.5  # ~6.6x MAC reduction for d=5
    # per-voxel form quoted in DESIGN.md: 4 + 16/d + 64/d^2 MACs/voxel
    per_voxel_sep = 4 + 16 / d + 64 / d**2
    assert abs(per_voxel_sep - sep / d**3) < 1e-9
    assert 64 / per_voxel_sep > 6.5  # -> 16x asymptotically in d
