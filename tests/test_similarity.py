"""The pluggable similarity subsystem: registry, gradients, multi-modal NMI."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ffd, metrics, similarity
from repro.core.registration import ffd_register
from repro.data.volumes import make_pair, make_phantom
from repro.engine import register_batch
from repro.engine.batch import ffd_level_loss

TILE = (6, 6, 6)


def _monotone_remap(v):
    """Monotone-decreasing intensity remap (synthetic cross-modality)."""
    return (1.0 - v) ** 1.5


# --- registry ----------------------------------------------------------------


def test_registry_contains_the_paper_terms():
    names = similarity.available_similarities()
    assert {"ssd", "ncc", "lncc", "nmi"} <= set(names)


def test_resolve_by_name_and_callable():
    key, fn = similarity.resolve_similarity("ssd")
    assert key == "ssd" and fn is similarity.ssd

    def custom(w, f):
        return jnp.mean(jnp.abs(w - f))

    key, fn = similarity.resolve_similarity(custom)
    assert key is custom and fn is custom


def test_resolve_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown similarity"):
        similarity.resolve_similarity("nosuch")


def test_factories_are_cached_by_parameters():
    # equal-parameter factories return the SAME callable, so compiled-runner
    # caches keyed on the callable hit across calls
    assert similarity.nmi(bins=48) is similarity.nmi(bins=48)
    assert similarity.lncc(window=5) is similarity.lncc(window=5)
    assert similarity.nmi(bins=48) is not similarity.nmi(bins=32)
    # tokens embed every factory parameter, so no two variants share an
    # autotune cache entry
    assert similarity.similarity_token(similarity.nmi(bins=48)) == \
        "nmi(bins=48,sigma_ratio=0.5,eps=1e-08)"
    assert (similarity.similarity_token(similarity.lncc(window=5))
            != similarity.similarity_token(similarity.lncc(window=5, eps=1e-4)))


def test_register_similarity_round_trip():
    @similarity.register_similarity("test_mae")
    def mae_loss(w, f):
        return jnp.mean(jnp.abs(w - f))

    try:
        key, fn = similarity.resolve_similarity("test_mae")
        assert key == "test_mae" and fn is mae_loss
    finally:
        similarity._REGISTRY.pop("test_mae")


# --- loss contract: lower = better, grads finite & non-zero under jit+vmap ---


@pytest.mark.parametrize("name", ["ssd", "ncc", "lncc", "nmi"])
def test_identical_pair_scores_lower(name):
    a = make_phantom((16, 14, 12), seed=0)
    b = make_phantom((16, 14, 12), seed=5)
    _, fn = similarity.resolve_similarity(name)
    assert float(fn(a, a)) < float(fn(b, a)) - 1e-4


@pytest.mark.parametrize("name", ["ssd", "ncc", "lncc", "nmi"])
def test_grad_finite_nonzero_under_jit_vmap(name):
    _, fn = similarity.resolve_similarity(name)
    a = make_phantom((12, 10, 9), seed=1)
    b = make_phantom((12, 10, 9), seed=2)
    grads = jax.jit(jax.vmap(jax.grad(fn)))(jnp.stack([a, b]),
                                            jnp.stack([b, a]))
    g = np.asarray(grads)
    assert np.all(np.isfinite(g))
    assert np.abs(g).sum() > 0.0


@pytest.mark.parametrize("name", ["ssd", "ncc", "lncc", "nmi"])
def test_level_loss_differentiable_per_similarity(name):
    """The full level objective (BSI + warp + similarity) under jit+grad."""
    fixed, moving, _ = make_pair(shape=(18, 16, 14), tile=TILE,
                                 magnitude=1.0, seed=4)
    loss_fn = ffd_level_loss(fixed, moving, tile=TILE, bending_weight=5e-3,
                             mode="separable", impl="jnp", similarity=name)
    gshape = ffd.grid_shape_for_volume(fixed.shape, TILE)
    phi = jnp.ones(gshape + (3,), jnp.float32) * 0.1
    loss, g = jax.jit(jax.value_and_grad(loss_fn))(phi)
    assert np.isfinite(float(loss))
    g = np.asarray(g)
    assert np.all(np.isfinite(g)) and np.abs(g).sum() > 0.0


# --- window clamping on tiny volumes (coarse pyramid levels) -----------------


def test_lncc_and_ssim_survive_sub_window_volumes():
    a = make_phantom((4, 4, 4), seed=0, n_tumors=1, n_vessels=0)
    b = make_phantom((4, 4, 4), seed=3, n_tumors=1, n_vessels=0)
    _, lncc = similarity.resolve_similarity("lncc")  # default window 9 > 4
    assert np.isfinite(float(lncc(a, b)))
    assert float(lncc(a, a)) < float(lncc(b, a))
    assert np.isfinite(float(metrics.ssim(a, b, window=7)))
    assert float(metrics.ssim(a, a)) > 0.999
    # non-cubic, one axis below the window
    c = make_phantom((12, 10, 4), seed=1, n_tumors=1, n_vessels=0)
    assert np.isfinite(float(lncc(c, c)))


# --- the acceptance scenario: multi-modal pair, SSD fails, NMI recovers ------


@pytest.mark.slow
def test_multimodal_nmi_beats_ssd():
    """Known FFD warp + monotone intensity remap: ``similarity="nmi"`` must
    land a lower post-registration MAE than the SSD run (which chases the
    inverted intensities), scored on the un-remapped moving volume warped by
    each recovered field."""
    shape = (28, 24, 20)
    fixed, moving, _ = make_pair(shape=shape, tile=TILE,
                                 magnitude=1.5, seed=2)
    remapped = _monotone_remap(moving)

    maes = {}
    for sim in ("ssd", "nmi"):
        res = ffd_register(fixed, remapped, tile=TILE, levels=2, iters=25,
                           similarity=sim, mode="separable", impl="jnp")
        disp = ffd.dense_field(res.params, TILE, shape)
        recovered = ffd.warp_volume(moving, disp)
        maes[sim] = float(metrics.mae(recovered, fixed))

    assert maes["nmi"] < maes["ssd"], maes
    # and NMI genuinely registers: better than not registering at all
    assert maes["nmi"] < float(metrics.mae(moving, fixed)), maes


@pytest.mark.slow
def test_register_batch_nmi_matches_per_pair():
    """Batched NMI registration == per-pair NMI registration (<= 1e-4)."""
    pairs = [make_pair(shape=(24, 20, 18), tile=TILE, magnitude=1.5, seed=s)
             for s in (0, 1)]
    fixed = jnp.stack([p[0] for p in pairs])
    moving = jnp.stack([p[1] for p in pairs])
    kw = dict(tile=TILE, levels=2, iters=6, lr=0.5, bending_weight=5e-3,
              mode="separable", impl="jnp", similarity="nmi")

    batch = register_batch(fixed, moving, **kw)
    for b, (f, m, _) in enumerate(pairs):
        single = ffd_register(f, m, **kw)
        np.testing.assert_allclose(np.asarray(batch.losses[b]),
                                   np.asarray(single.losses),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(batch.warped[b]),
                                   np.asarray(single.warped), atol=1e-4)


def test_register_batch_accepts_callable_similarity():
    pairs = [make_pair(shape=(18, 16, 14), tile=TILE, magnitude=1.0, seed=s)
             for s in (0, 1)]
    fixed = jnp.stack([p[0] for p in pairs])
    moving = jnp.stack([p[1] for p in pairs])
    out = register_batch(fixed, moving, tile=TILE, levels=1, iters=3,
                         mode="separable", impl="jnp",
                         similarity=similarity.nmi(bins=16))
    assert out.warped.shape == fixed.shape
    assert np.all(np.isfinite(np.asarray(out.losses)))
