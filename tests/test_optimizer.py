"""Optimiser registry (ISSUE 10): the pluggable ``optimizer=`` layer.

Covers the tentpole (``engine.optimizer``: specs/registry/``opt_step``,
``optimize_scan``/``optimize_until`` generic loops, the ``optimizer=``
field on ``RegistrationOptions`` threaded through ``register_batch`` /
``ffd_register`` / the sharded and serving paths) and the satellites that
ride along: ``fused_reason`` introspection, the rejected-step patience
semantics, and the ``optimizer=`` legacy-kwarg deprecation shim.

The two load-bearing claims:

* ``optimizer="adam"`` (the default) is *bit-identical* to the
  pre-registry engine — same arithmetic, same trace, same params.
* The second-order entries earn their keep: on a hard pair, L-BFGS and
  Gauss-Newton reach a final loss at least as good as Adam's full budget
  in a quarter of the steps.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ffd
from repro.core.options import (RegistrationOptions,
                                _reset_deprecation_registry)
from repro.core.registration import ffd_register
from repro.data.volumes import make_pair
from repro.engine import (ConvergenceConfig, adam_scan,
                          make_registration_mesh, optimize_scan,
                          optimize_until, register_batch)
from repro.engine.autotune import resolve_options
from repro.engine.batch import ffd_level_loss, ffd_level_objective
from repro.engine.optimizer import (AdamOptimizer, GaussNewtonOptimizer,
                                    LbfgsOptimizer, available_optimizers,
                                    gauss_newton, init_state, lbfgs,
                                    make_objective, opt_step,
                                    optimizer_token, resolve_optimizer)

TILE = (6, 6, 6)
SHAPE = (22, 20, 18)
KW = dict(tile=TILE, levels=2, iters=24, lr=0.1, mode="separable",
          impl="jnp")
LEVEL_KW = dict(tile=TILE, bending_weight=1e-3, mode="separable", impl="jnp")


def _stack(mags):
    pairs = [make_pair(shape=SHAPE, tile=TILE, magnitude=m, seed=s)
             for s, m in enumerate(mags)]
    return (jnp.stack([p[0] for p in pairs]),
            jnp.stack([p[1] for p in pairs]))


# --------------------------------------------------------------- registry

def test_registry_names_resolution_and_tokens():
    names = available_optimizers()
    assert {"adam", "lbfgs", "gauss_newton"} <= set(names)
    assert resolve_optimizer("adam") == AdamOptimizer()
    assert resolve_optimizer("lbfgs") == LbfgsOptimizer()
    spec = lbfgs(history=3)
    assert resolve_optimizer(spec) is spec  # passthrough
    with pytest.raises(Exception):
        resolve_optimizer("newton_raphson")
    # the default Adam keeps the historical token (autotune disk cache
    # entries written before the registry stay valid)
    assert optimizer_token("adam") == "adam"
    assert optimizer_token(AdamOptimizer()) == "adam"
    assert optimizer_token(AdamOptimizer(b1=0.8)) != "adam"
    assert optimizer_token("lbfgs") != optimizer_token(lbfgs(history=3))
    assert "gauss_newton" in optimizer_token(gauss_newton())


def test_spec_validation():
    with pytest.raises(ValueError):
        AdamOptimizer(b1=1.0)
    with pytest.raises(ValueError):
        LbfgsOptimizer(history=0)
    with pytest.raises(ValueError):
        LbfgsOptimizer(shrink=1.5)
    with pytest.raises(ValueError):
        GaussNewtonOptimizer(cg_iters=0)
    with pytest.raises(ValueError):
        GaussNewtonOptimizer(damp_up=0.5)


def test_options_resolve_optimizer_and_stay_hashable():
    o = RegistrationOptions(**KW, optimizer="lbfgs")
    assert o.optimizer == LbfgsOptimizer()  # resolved to the frozen spec
    assert hash(o)  # lru_cache key material
    assert o != RegistrationOptions(**KW)  # optimizer is part of identity
    # gauss_newton needs the SSD residual form and an unfused level step
    with pytest.raises(ValueError, match="gauss_newton"):
        RegistrationOptions(**KW, optimizer="gauss_newton",
                            similarity="ncc")
    with pytest.raises(ValueError, match="gauss_newton"):
        RegistrationOptions(**KW, optimizer="gauss_newton", fused="on")


# ----------------------------------------------------- adam bit-identity

def test_optimize_scan_adam_is_bitwise_adam_scan():
    """The registry's adam entry is the pre-registry loop, bit for bit."""
    f, m, _ = make_pair(shape=SHAPE, tile=TILE, magnitude=1.5, seed=0)
    loss_fn = ffd_level_loss(f, m, **LEVEL_KW)
    gshape = ffd.grid_shape_for_volume(f.shape, TILE)
    phi0 = jnp.zeros(gshape + (3,), jnp.float32)

    p_old, t_old = adam_scan(loss_fn, phi0, iters=8, lr=0.1)
    p_new, t_new = optimize_scan(make_objective(loss_fn), phi0,
                                 optimizer="adam", iters=8, lr=0.1)
    assert np.array_equal(np.asarray(p_old), np.asarray(p_new))
    assert np.array_equal(np.asarray(t_old), np.asarray(t_new))


def test_ffd_pipeline_adam_is_bitwise_pre_registry_pipeline():
    """The full default pipeline matches a verbatim reconstruction of the
    pre-registry per-level loop (pyramid + ``adam_scan``) exactly."""
    from repro.engine.batch import ffd_pipeline

    f, m, _ = make_pair(shape=SHAPE, tile=TILE, magnitude=1.5, seed=1)
    kw = dict(KW)
    iters, lr = 6, kw.pop("lr")
    kw.pop("iters"), kw.pop("levels")

    # pre-registry reference: the seed's level loop, Adam welded in
    pyramid = [(f, m), (ffd.downsample2(f), ffd.downsample2(m))][::-1]
    phi = None
    finals = []
    for lf, lm in pyramid:
        gshape = ffd.grid_shape_for_volume(lf.shape, TILE)
        phi = (jnp.zeros(gshape + (3,), jnp.float32) if phi is None
               else ffd.upsample_grid(phi, gshape))
        loss_fn = ffd_level_loss(lf, lm, **LEVEL_KW)
        phi, trace = adam_scan(loss_fn, phi, iters=iters, lr=lr)
        finals.append(trace[-1])

    _, phi_new, losses = ffd_pipeline(
        f, m, levels=2, iters=iters, lr=lr, **LEVEL_KW)
    assert np.array_equal(np.asarray(phi), np.asarray(phi_new))
    assert np.array_equal(np.asarray(jnp.stack(finals)), np.asarray(losses))


# ------------------------------------------- second-order: earn your keep

@pytest.mark.parametrize("optimizer", ["lbfgs", "gauss_newton"])
def test_second_order_quarter_budget_beats_adam(optimizer):
    """Acceptance: on the benchmarked hard pair (magnitude-2.5 deformation,
    pure-SSD objective — the regime where Adam's fixed per-coordinate step
    costs it the tail), the second-order entries reach a final loss <=
    Adam's in <= 25% of Adam's steps.  The same configuration backs the
    ``registration_bench --optimizers`` rows."""
    f, m, _ = make_pair(shape=SHAPE, tile=TILE, magnitude=2.5, seed=1)
    kw = dict(KW, bending_weight=0.0)
    adam_res = ffd_register(
        f, m, options=RegistrationOptions(**dict(kw, iters=48)))
    fast = ffd_register(
        f, m, options=RegistrationOptions(**dict(kw, iters=12),
                                          optimizer=optimizer))
    assert fast.losses[-1] <= adam_res.losses[-1]


def test_gauss_newton_requires_residual_objective():
    obj = make_objective(lambda p: jnp.sum(p * p))  # scalar-only
    p = jnp.zeros(3)
    g = jnp.zeros(3)
    loss = jnp.float32(0.0)
    with pytest.raises(ValueError, match="residual"):
        opt_step(GaussNewtonOptimizer(), obj, jnp.int32(0), p,
                 init_state(GaussNewtonOptimizer(), p), g, loss, lr=0.1)


def test_gauss_newton_rejected_step_raises_damping_keeps_iterate():
    """At a point no trial can strictly improve, the LM fallback rejects
    (``ok=False``), multiplies the damping, and does not move."""
    spec = GaussNewtonOptimizer()
    obj = make_objective(None, residual_fn=lambda p: p)  # optimum at 0
    p = jnp.zeros(3)
    opt = init_state(spec, p)
    loss, g = obj.vg(p)
    p1, opt1, g1, loss1, ok = opt_step(spec, obj, jnp.int32(0), p, opt, g,
                                       loss.astype(jnp.float32), lr=0.1)
    assert not bool(ok)
    assert np.array_equal(np.asarray(p1), np.asarray(p))
    np.testing.assert_allclose(float(opt1["damping"]),
                               float(opt["damping"]) * spec.damp_up)


# ------------------------------------- line-search collapse + patience

def test_lbfgs_line_search_collapse_freezes_not_nans():
    """Satellite: a lane whose Armijo search can never accept must freeze
    via the patience rule — rejected steps are not progress — and keep a
    finite iterate, not NaN out.

    The trap objective is finite (with a finite, non-zero gradient) only
    at the start point; every trial step the line search evaluates is NaN,
    so every backtrack fails and ``opt_step`` reports ``ok=False``.
    """
    direction = jnp.array([1.0, 2.0, -1.0])

    def trap(p):
        moved = jnp.any(p != 0.0)
        return jnp.where(moved, jnp.nan, jnp.sum(direction * p) + 1.0)

    obj = make_objective(trap)
    stop = ConvergenceConfig(tol=1e-6, patience=3).resolve(50)
    best_p, trace, k = optimize_until(obj, jnp.zeros(3), optimizer="lbfgs",
                                      stop=stop, lr=1.0)
    assert int(k) == 3  # patience exhausts; the budget (50) never does
    assert np.array_equal(np.asarray(best_p), np.zeros(3))  # never moved
    assert np.all(np.isfinite(np.asarray(trace)))  # padded with best, no NaN
    assert float(trace[-1]) == 1.0  # the start loss is the best loss


def test_lbfgs_state_is_fp32_under_bf16_compute():
    f, m, _ = make_pair(shape=SHAPE, tile=TILE, magnitude=1.0, seed=2)
    obj = ffd_level_objective(f, m, **dict(LEVEL_KW,
                                           compute_dtype="bfloat16"))
    gshape = ffd.grid_shape_for_volume(f.shape, TILE)
    phi0 = jnp.zeros(gshape + (3,), jnp.float32)
    state = init_state(LbfgsOptimizer(), phi0)
    assert state["s"].dtype == jnp.float32
    assert state["y"].dtype == jnp.float32
    assert state["rho"].dtype == jnp.float32
    p, trace = optimize_scan(obj, phi0, optimizer="lbfgs", iters=3, lr=0.1)
    assert p.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(p)))
    assert np.all(np.isfinite(np.asarray(trace)))


# ------------------------------------------------- composition parity

@pytest.mark.parametrize("optimizer", ["lbfgs", "gauss_newton"])
def test_vmap_batch_matches_solo(optimizer):
    F, M = _stack([0.8, 1.6])
    opts = RegistrationOptions(**dict(KW, iters=6), optimizer=optimizer)
    batch = register_batch(F, M, options=opts)
    for i in range(2):
        solo = ffd_register(F[i], M[i], options=opts)
        np.testing.assert_allclose(np.asarray(batch.params[i]),
                                   np.asarray(solo.params), atol=1e-4)
        np.testing.assert_allclose(np.asarray(batch.warped[i]),
                                   np.asarray(solo.warped), atol=1e-4)


@pytest.mark.parametrize("optimizer", ["lbfgs", "gauss_newton"])
def test_mesh_sharded_matches_unsharded(optimizer):
    F, M = _stack([0.8, 1.6, 1.2])
    opts = RegistrationOptions(**dict(KW, iters=6), optimizer=optimizer)
    base = register_batch(F, M, options=opts)
    res = register_batch(F, M, mesh=make_registration_mesh(), options=opts)
    np.testing.assert_allclose(np.asarray(res.params),
                               np.asarray(base.params), atol=1e-4)
    np.testing.assert_allclose(np.asarray(res.warped),
                               np.asarray(base.warped), atol=1e-4)


@pytest.mark.parametrize("optimizer", ["lbfgs", "gauss_newton"])
def test_early_stop_composes_with_second_order(optimizer):
    """An easy pair under ``stop=`` exits before the budget and the batch
    path agrees with the solo path (frozen-lane masking included)."""
    stop = ConvergenceConfig(tol=5e-3, patience=4)
    opts = RegistrationOptions(**KW, optimizer=optimizer, stop=stop)
    f, m, _ = make_pair(shape=SHAPE, tile=TILE, magnitude=0.6, seed=5)
    solo = ffd_register(f, m, options=opts)
    assert solo.steps is not None
    assert any(s < KW["iters"] for s in solo.steps)  # actually stopped early
    F, M = _stack([0.6, 2.0])
    batch = register_batch(F, M, options=opts)
    solo0 = ffd_register(F[0], M[0], options=opts)
    np.testing.assert_allclose(np.asarray(batch.params[0]),
                               np.asarray(solo0.params), atol=1e-4)


def test_serve_splice_matches_solo_lbfgs():
    """Lane recycling with a second-order optimiser: a spliced request's
    nested optimiser state (curvature window, not just m/v) must restart
    cleanly, so a recycled pair matches solo ``ffd_register``.

    The hard pairs are deliberately *contractive* (moderate deformation the
    optimiser actually solves): a leaked curvature pair would still diverge
    grossly, while on a non-convergent pair L-BFGS's discrete line-search
    accept/reject would amplify vectorisation-level fp noise into trajectory
    splits and the parity assertion would test chaos, not splice hygiene."""
    from repro.engine.serve import RegistrationScheduler

    # grad_impl pinned: with "auto" the serve lanes and the solo reference
    # may autotune different gradient winners (fresh cache under pytest),
    # and any arithmetic difference bifurcates the discrete line search
    opts = RegistrationOptions(**dict(KW, iters=12), optimizer="lbfgs",
                               grad_impl="jnp",
                               stop=ConvergenceConfig(tol=2e-3, patience=3))
    rng = np.random.default_rng(0)
    base = rng.normal(size=SHAPE).astype(np.float32)
    x, y, z = np.meshgrid(*[np.linspace(0, np.pi, s) for s in SHAPE],
                          indexing="ij")
    wave = (np.sin(x) * np.sin(y) * np.sin(z)).astype(np.float32)
    pairs = []
    for i in range(4):
        f = base + 0.05 * rng.normal(size=SHAPE).astype(np.float32)
        if i % 3 == 0:  # harder pair: holds its lane while others drain
            m = f + 0.3 * wave
        else:
            m = f + 0.02 * wave
        pairs.append((f, m.astype(np.float32)))
    sched = RegistrationScheduler(opts, lanes=2, chunk=2, max_queue=8)
    handles = [sched.submit(f, m) for f, m in pairs]
    sched.run_until_idle()
    assert sched.stats.completed == len(pairs)
    assert sched.stats.recycled > 0  # splicing actually happened
    for (f, m), h in zip(pairs, handles):
        served = h.result()
        solo = ffd_register(f, m, options=opts)
        assert served.steps == solo.steps
        np.testing.assert_allclose(np.asarray(served.warped),
                                   np.asarray(solo.warped), atol=1e-4)


def test_program_cache_keys_on_optimizer():
    """Two options differing only in ``optimizer=`` must never share a
    compiled program; re-using either hits its own cache entry."""
    F, M = _stack([0.9])
    o_adam = RegistrationOptions(**dict(KW, iters=3))
    o_lbfgs = RegistrationOptions(**dict(KW, iters=3), optimizer="lbfgs")
    assert register_batch(F, M, options=o_adam).compiled
    assert register_batch(F, M, options=o_lbfgs).compiled  # distinct program
    assert not register_batch(F, M, options=o_adam).compiled  # cache hit


# ------------------------------------------------ fused_reason (satellite)

def test_fused_reason_is_introspectable_and_not_identity():
    o = resolve_options(RegistrationOptions(**KW, fused="off"), SHAPE)
    assert o.fused == "off"
    assert o.fused_reason == "forced off"

    o = resolve_options(RegistrationOptions(**KW, fused="auto",
                                            transform="velocity"), SHAPE)
    assert o.fused == "off"
    assert "velocity" in o.fused_reason

    o = resolve_options(RegistrationOptions(**KW, fused="auto",
                                            optimizer="gauss_newton"), SHAPE)
    assert o.fused == "off"
    assert "gauss_newton" in o.fused_reason

    # the reason is a diagnostic, not identity: it never fragments caches
    a = resolve_options(RegistrationOptions(**KW, fused="off"), SHAPE)
    b = dataclasses.replace(a, fused_reason="something else")
    assert a == b
    assert hash(a) == hash(b)


# -------------------------------------------------- deprecation shim

def test_optimizer_legacy_kwarg_warns_once_per_site():
    _reset_deprecation_registry()
    f, m, _ = make_pair(shape=SHAPE, tile=TILE, magnitude=0.8, seed=7)

    def call():
        return ffd_register(f, m, tile=TILE, levels=1, iters=2, lr=0.1,
                            mode="separable", impl="jnp", optimizer="lbfgs")

    with pytest.warns(DeprecationWarning, match="optimizer"):
        call()
    import warnings as _w
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        call()  # same call site: already warned
    assert not [w for w in caught if issubclass(w.category,
                                                DeprecationWarning)]
    with pytest.raises(TypeError, match="not both"):
        ffd_register(f, m, options=RegistrationOptions(**KW),
                     optimizer="lbfgs")
