"""Core B-spline math + jnp algorithm-form tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bspline import bspline_basis, lerp_luts, weight_lut
from repro.core.interpolate import MODES, bsi_gather, interpolate
from repro.kernels.ref import bsi_points_ref, bsi_ref


def test_basis_partition_of_unity():
    u = jnp.linspace(0.0, 1.0, 101)
    b = bspline_basis(u)
    np.testing.assert_allclose(np.asarray(b.sum(-1)), 1.0, atol=1e-6)


def test_basis_nonnegative_and_symmetric():
    u = jnp.linspace(0.0, 1.0, 33)
    b = np.asarray(bspline_basis(u))
    assert (b >= -1e-7).all()
    # B_l(u) == B_{3-l}(1-u)
    b_rev = np.asarray(bspline_basis(1.0 - u))
    np.testing.assert_allclose(b, b_rev[:, ::-1], atol=1e-6)


def test_weight_lut_matches_basis():
    for d in (3, 4, 5, 6, 7):
        lut = np.asarray(weight_lut(d))
        u = np.arange(d) / d
        direct = np.asarray(bspline_basis(jnp.asarray(u, jnp.float32)))
        np.testing.assert_allclose(lut, direct, atol=1e-6)


def test_lerp_luts_reconstruct_weights():
    for d in (3, 5, 7):
        w = np.asarray(weight_lut(d), np.float64)
        t0, t1, s = (np.asarray(a, np.float64) for a in lerp_luts(d))
        # lerp chain applied to the 4 unit vectors reproduces the weights
        for l in range(4):
            p = np.zeros(4)
            p[l] = 1.0
            h01 = p[0] + t0 * (p[1] - p[0])
            h23 = p[2] + t1 * (p[3] - p[2])
            out = h01 + s * (h23 - h01)
            np.testing.assert_allclose(out, w[:, l], atol=1e-6)


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize(
    "grid,tile",
    [((7, 6, 5), (5, 4, 3)), ((4, 4, 4), (5, 5, 5)), ((6, 8, 4), (7, 3, 6))],
)
def test_modes_match_oracle(mode, grid, tile):
    rng = np.random.default_rng(0)
    phi = jnp.asarray(rng.standard_normal(grid + (3,)), jnp.float32)
    ref = bsi_ref(phi, tile)
    out = interpolate(phi, tile, mode=mode, impl="jnp")
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_points_ref_agrees_on_aligned_coords():
    rng = np.random.default_rng(2)
    phi = jnp.asarray(rng.standard_normal((6, 6, 6, 2)), jnp.float32)
    tile = (4, 4, 4)
    ref = bsi_ref(phi, tile)
    X, Y, Z = ref.shape[:3]
    pts = jnp.stack(
        jnp.meshgrid(jnp.arange(X), jnp.arange(Y), jnp.arange(Z), indexing="ij"),
        -1,
    ).astype(jnp.float32)
    out = bsi_points_ref(phi, pts, tile)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_constant_grid_gives_constant_field():
    phi = jnp.full((6, 5, 7, 3), 2.5, jnp.float32)
    out = bsi_gather(phi, (5, 5, 5))
    np.testing.assert_allclose(np.asarray(out), 2.5, atol=1e-5)


def test_bsi_gradient_matches_finite_differences():
    """Registration optimises control points by autodiff through BSI —
    verify d(loss)/d(phi) against central finite differences."""
    import jax

    rng = np.random.default_rng(11)
    phi = jnp.asarray(rng.standard_normal((5, 5, 5, 2)), jnp.float32)
    target = jnp.asarray(rng.standard_normal((8, 8, 8, 2)), jnp.float32)
    tile = (4, 4, 4)

    def loss(p):
        from repro.core.interpolate import bsi_separable
        return jnp.mean((bsi_separable(p, tile) - target) ** 2)

    g = jax.grad(loss)(phi)
    eps = 1e-2
    for idx in [(0, 0, 0, 0), (2, 3, 1, 1), (4, 4, 4, 0)]:
        lp = loss(phi.at[idx].add(eps))
        lm = loss(phi.at[idx].add(-eps))
        fd = (lp - lm) / (2 * eps)
        np.testing.assert_allclose(float(g[idx]), float(fd), atol=2e-3)


def test_modes_agree_under_jit_and_grad():
    """grad through every mode gives the same gradient (linearity of BSI)."""
    import jax
    from repro.core.interpolate import MODES

    rng = np.random.default_rng(12)
    phi = jnp.asarray(rng.standard_normal((5, 5, 5, 1)), jnp.float32)
    tile = (3, 3, 3)
    grads = {}
    for mode, fn in MODES.items():
        g = jax.grad(lambda p: jnp.sum(jnp.sin(fn(p, tile))))(phi)
        grads[mode] = np.asarray(g)
    base = grads.pop("gather")
    for mode, g in grads.items():
        np.testing.assert_allclose(g, base, atol=1e-4), mode


def test_nonuniform_matches_aligned_at_integer_spacing():
    """Paper §8 future work: non-uniform path reduces to the aligned one
    when the spacing happens to be integer."""
    from repro.core.nonuniform import bsi_nonuniform

    rng = np.random.default_rng(13)
    phi = jnp.asarray(rng.standard_normal((7, 6, 5, 2)), jnp.float32)
    ref = bsi_ref(phi, (5, 4, 3))
    out = bsi_nonuniform(phi, (5.0, 4.0, 3.0), ref.shape[:3])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_nonuniform_matches_points_ref_at_fractional_spacing():
    from repro.core.nonuniform import bsi_nonuniform, grid_points_for_spacing

    rng = np.random.default_rng(14)
    spacing = (4.7, 3.3, 5.9)
    vol = (17, 13, 19)
    gshape = grid_points_for_spacing(vol, spacing)
    phi = jnp.asarray(rng.standard_normal(gshape + (2,)), jnp.float32)
    out = bsi_nonuniform(phi, spacing, vol)
    # oracle: evaluate Eq. (1) at every voxel with continuous coordinates
    xs, ys, zs = jnp.meshgrid(*(jnp.arange(s, dtype=jnp.float32) for s in vol),
                              indexing="ij")
    pts = jnp.stack([xs, ys, zs], -1)
    ref = bsi_points_ref(phi, pts, spacing)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)


def test_nonuniform_constant_reproduction():
    from repro.core.nonuniform import bsi_nonuniform

    phi = jnp.full((8, 8, 8, 1), -1.75, jnp.float32)
    out = bsi_nonuniform(phi, (2.6, 3.1, 4.9), (12, 12, 12))
    np.testing.assert_allclose(np.asarray(out), -1.75, atol=1e-5)
