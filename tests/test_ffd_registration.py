"""FFD, warping, metrics and the end-to-end registration behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ffd, metrics
from repro.core.registration import affine_register, downsample2, ffd_register
from repro.data.volumes import make_pair, make_phantom


def test_grid_shape_covers_volume():
    assert ffd.grid_shape_for_volume((80, 75, 70), (5, 5, 5)) == (19, 18, 17)
    # 16 tiles cover 80; +3 halo
    assert ffd.grid_shape_for_volume((81, 75, 70), (5, 5, 5))[0] == 20


def test_dense_field_crops_to_volume():
    phi = jnp.zeros((6, 6, 6, 3), jnp.float32)
    out = ffd.dense_field(phi, (5, 5, 5), (13, 14, 15))
    assert out.shape == (13, 14, 15, 3)


def test_warp_identity():
    vol = make_phantom((24, 20, 18))
    disp = jnp.zeros(vol.shape + (3,), jnp.float32)
    warped = ffd.warp_volume(vol, disp)
    np.testing.assert_allclose(np.asarray(warped), np.asarray(vol), atol=1e-6)


def test_warp_integer_shift():
    vol = make_phantom((24, 20, 18))
    disp = jnp.zeros(vol.shape + (3,), jnp.float32).at[..., 0].set(1.0)
    warped = ffd.warp_volume(vol, disp)
    np.testing.assert_allclose(
        np.asarray(warped[:-1]), np.asarray(vol[1:]), atol=1e-6)


def test_trilinear_sample_midpoint():
    vol = jnp.asarray(np.arange(8, dtype=np.float32).reshape(2, 2, 2))
    mid = ffd.trilinear_sample(vol, jnp.asarray([[0.5, 0.5, 0.5]]))
    assert abs(float(mid[0]) - float(vol.mean())) < 1e-6


def test_bending_energy_zero_for_affine_grid():
    xs = jnp.arange(8.0)[:, None, None, None]
    phi = jnp.broadcast_to(xs * 2.0 + 1.0, (8, 8, 8, 3))
    assert float(ffd.bending_energy(phi)) < 1e-8
    rng = np.random.default_rng(0)
    noisy = phi + jnp.asarray(rng.standard_normal(phi.shape), jnp.float32)
    assert float(ffd.bending_energy(noisy)) > 1e-2


def test_metrics_basics():
    a = make_phantom((20, 18, 16), seed=0)
    assert float(metrics.ssim(a, a)) > 0.999
    assert float(metrics.mae(a, a)) < 1e-7
    assert abs(float(metrics.ncc(a, a)) - 1.0) < 1e-5
    # different tumour/vessel placement, same parenchyma: similar but not equal
    b = make_phantom((20, 18, 16), seed=5)
    assert float(metrics.ssim(a, b)) < float(metrics.ssim(a, a)) - 1e-3


def test_downsample2():
    v = jnp.ones((10, 8, 6), jnp.float32)
    assert downsample2(v).shape == (5, 4, 3)


@pytest.mark.slow
def test_ffd_registration_improves_similarity():
    fixed, moving, _ = make_pair(shape=(40, 36, 32), tile=(6, 6, 6),
                                 magnitude=1.8, seed=0)
    pre = float(metrics.ssim(moving, fixed))
    res = ffd_register(fixed, moving, tile=(6, 6, 6), levels=2, iters=25)
    post = float(metrics.ssim(res.warped, fixed))
    assert post > pre + 0.02, (pre, post)
    assert float(metrics.mae(res.warped, fixed)) < float(metrics.mae(moving, fixed))


@pytest.mark.slow
def test_registration_mode_equivalence():
    """All BSI modes drive registration to the same solution (paper §7:
    'same accuracy as state of the art')."""
    fixed, moving, _ = make_pair(shape=(32, 28, 24), tile=(6, 6, 6),
                                 magnitude=1.5, seed=1)
    outs = {}
    for mode in ("gather", "separable"):
        res = ffd_register(fixed, moving, tile=(6, 6, 6), levels=1, iters=15,
                           mode=mode)
        outs[mode] = np.asarray(res.warped)
    np.testing.assert_allclose(outs["gather"], outs["separable"],
                               atol=1e-3, rtol=1e-3)


def test_affine_register_recovers_translation():
    vol = make_phantom((36, 32, 28), seed=2)
    disp = jnp.zeros(vol.shape + (3,), jnp.float32).at[..., 0].set(2.0)
    moving = ffd.warp_volume(vol, disp)
    res = affine_register(vol, moving, iters=80, lr=0.05)
    assert float(metrics.ssim(res.warped, vol)) > float(metrics.ssim(moving, vol))
