"""Validate the trip-count-scaling HLO analyzer against unrolled oracles."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (analyze_hlo, buffer_shapes,
                                       materializes_shape)


def _flops(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(txt)


def test_scan_matches_unroll_flops():
    def f_scan(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    def f_unroll(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    s_scan = _flops(f_scan, x, w)
    s_unr = _flops(f_unroll, x, w)
    analytic = 2 * 128 * 256 * 256 * 8
    assert s_scan.flops == analytic, (s_scan.flops, analytic)
    assert s_unr.flops == analytic
    assert s_scan.while_trips == [8]


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.sin(c2 @ wi), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    s = _flops(f, x, w)
    analytic = 2 * 64 * 64 * 64 * 3 * 5
    assert s.flops == analytic, (s.flops, analytic)


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 48, 16), jnp.float32)
    s = _flops(f, a, b)
    assert s.flops == 2 * 4 * 32 * 48 * 16


def test_bytes_scale_with_trip_count():
    def f(x):
        def body(c, _):
            return c * 2.0 + 1.0, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    s = _flops(f, x)
    # each iteration reads + writes ~4MB; 10 iterations >= 80MB
    assert s.bytes_accessed >= 10 * 2 * 4 * 1024 * 1024 * 0.9


def test_buffer_shapes_and_materializes_shape():
    def f(a, b):
        return (a @ b).T  # transposed output: axis order must not matter

    a = jax.ShapeDtypeStruct((17, 23), jnp.float32)
    b = jax.ShapeDtypeStruct((23, 5), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    shapes = {s for _, s in buffer_shapes(txt)}
    assert (17, 23) in shapes and (23, 5) in shapes
    assert materializes_shape(txt, (17, 5))   # the product, any layout
    assert materializes_shape(txt, (5, 17))   # ... order-insensitive
    assert not materializes_shape(txt, (17, 23, 5))


def test_fused_level_step_never_materializes_dense_field():
    """The tentpole claim, statically: the fused level-step lowering never
    even NAMES an (X, Y, Z, 3)-extent buffer — the dense displacement field
    exists only as per-block VMEM tiles — while the unfused composition
    (the positive control, proving the probe can see it) does.  Block tiles
    are pinned below the full grid so the per-block shapes cannot
    accidentally equal the dense field's."""
    import numpy as np

    from repro.core import ffd
    from repro.kernels import ops

    vol, tile = (12, 11, 9), (3, 3, 3)
    g = ffd.grid_shape_for_volume(vol, tile)
    rng = np.random.default_rng(0)
    phi = jnp.asarray(rng.standard_normal(g + (3,)), jnp.float32)
    mov = jnp.asarray(rng.random(vol), jnp.float32)
    fix = jnp.asarray(rng.random(vol), jnp.float32)

    def fused(p, m, f):
        return ops.fused_similarity_loss(p, m, f, tile, sim_spec=("ssd",),
                                         block_tiles=(1, 1, 1))

    def unfused(p, m, f):
        disp = ffd.dense_field(p, tile, vol)
        return jnp.mean((ffd.warp_volume(m, disp) - f) ** 2)

    fused_txt = jax.jit(fused).lower(phi, mov, fix).compile().as_text()
    unfused_txt = jax.jit(unfused).lower(phi, mov, fix).compile().as_text()
    assert not materializes_shape(fused_txt, vol + (3,))
    assert materializes_shape(unfused_txt, vol + (3,))


def test_collective_bytes_counted_inside_loops():
    """Needs >1 device -> fresh process with forced host devices."""
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as PS
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((2,), ("d",), devices=jax.devices()[:2])
        def f(x):
            def body(c, _):
                s = jax.lax.with_sharding_constraint(c, PS("d", None))
                return jnp.tanh(s @ s.T @ s), None
            y, _ = jax.lax.scan(body, x, None, length=4)
            return y
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
        with ctx:
            txt = jax.jit(f).lower(x).compile().as_text()
        s = analyze_hlo(txt)
        n = sum(s.collective_counts.values())
        assert n > 0, "expected collectives inside the loop"
        assert all(c % 4 == 0 for c in s.collective_counts.values() if c), s.collective_counts
        print("COLL_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "COLL_OK" in r.stdout, r.stderr[-2000:]
