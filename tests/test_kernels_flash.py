"""Flash-attention Pallas kernel vs the attend_full oracle (interpret)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import attend_full


def _mk(B, S, H, KV, hd, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (2, 64, 4, 4, 16),    # MHA
    (1, 128, 8, 2, 32),   # GQA 4:1
    (2, 64, 4, 1, 16),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(B, S, H, KV, hd, causal):
    q, k, v = _mk(B, S, H, KV, hd)
    pos = jnp.arange(S)
    ref = attend_full(q, k, v, q_positions=pos, k_positions=pos, causal=causal)
    out = flash_attention_pallas(q, k, v, causal=causal,
                                 block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_sliding_window(window):
    q, k, v = _mk(1, 64, 4, 4, 16, seed=1)
    pos = jnp.arange(64)
    ref = attend_full(q, k, v, q_positions=pos, k_positions=pos,
                      causal=True, window=window)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_softcap():
    q, k, v = _mk(1, 32, 2, 2, 16, seed=2)
    pos = jnp.arange(32)
    ref = attend_full(q, k, v, q_positions=pos, k_positions=pos,
                      causal=True, softcap=30.0)
    out = flash_attention_pallas(q, k, v, causal=True, softcap=30.0,
                                 block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    q, k, v = _mk(1, 64, 4, 2, 16, seed=3, dtype=dtype)
    pos = jnp.arange(64)
    ref = attend_full(q.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), q_positions=pos, k_positions=pos)
    out = flash_attention_pallas(q, k, v, block_q=32, block_kv=32)
    atol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=atol, rtol=atol)
